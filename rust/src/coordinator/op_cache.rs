//! Byte-bounded LRU cache of block-encoded operand planes.
//!
//! Serving workloads reuse operands heavily: a matmul lane typically
//! multiplies many activation batches against a small pool of weight
//! matrices, and a FIR lane convolves many signals against a fixed tap
//! set. The planar executors re-encode those operands into RNS planes on
//! every job, and at small-to-moderate shapes that block encode dominates
//! the per-job cost. This cache keys the *encoded* form of the reusable
//! operand by a content digest of its raw `f64` bits plus the precision
//! tier it was encoded under, so repeat jobs skip straight to the lane
//! kernels.
//!
//! Correctness invariants:
//!
//! * **Bit-identity.** An entry is only ever consulted by the executor
//!   that would have produced the exact same encode: the digest covers
//!   the operand's exact IEEE bits (no NaN/−0 canonicalization — see
//!   [`crate::hybrid::auth::operand_digest`]) plus a per-call-site salt,
//!   and the tier is part of the key, so a hit replays a bit-identical
//!   plane. Integration tests pin cache-served results against
//!   cold-encode results with `to_bits` equality.
//! * **Authenticated entries are epoch-scoped.** MAC lanes are derived
//!   per job *from* the cached plane (never stored in it), so a cached
//!   operand is key-independent; still, authenticated entries carry the
//!   cache's auth epoch in their key so [`OpCache::bump_auth_epoch`] can
//!   strand them wholesale (e.g. on a suspected-compromise rotation)
//!   without touching unauthenticated traffic.
//! * **Mutation never leaks back.** Executors that mutate the encoded
//!   operand in place (the fault-injection hooks corrupt the
//!   authenticated FIR tap plane) clone the cached value first; the
//!   shared entry is immutable behind its `Arc`.
//!
//! The cache is a plain `Mutex<HashMap>` with an O(entries) least-
//! recently-used eviction scan — entry counts are small (weight pools,
//! tap sets), the values are large, and the budget is enforced in bytes,
//! so scan cost is noise next to one block encode.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::hybrid_exec::DotBatchEncoded;
use crate::hybrid::registry::Tier;
use crate::hybrid::{Hrfna, HrfnaBatch};

/// One cached block-encoded operand. Three shapes, matching the three
/// executor paths that re-encode a reusable operand per job:
///
/// * [`CachedOperand::Batch`] — a matmul RHS, transposed and
///   block-encoded (`encode_matmul_rhs`).
/// * [`CachedOperand::Taps`] — a FIR tap vector, per-element encoded
///   exactly as `fir_filter`'s own `N::from_f64` loop would.
/// * [`CachedOperand::DotBatch`] — the authenticated-FIR reversed tap
///   plane (`encode_dot_batch`), cloned per job before MAC derivation
///   and fault injection.
/// * [`CachedOperand::Rk4Coeffs`] — the pre-encoded scalar constants of
///   an RK4 job's vector field (`workloads::rk4::Rk4Coeffs`), keyed by
///   the ODE's constants so every step of every repeat integration
///   shares one encode.
pub enum CachedOperand {
    /// Block-encoded matmul right-hand side (already transposed).
    Batch(HrfnaBatch),
    /// Per-element encoded FIR taps.
    Taps(Vec<Hrfna>),
    /// Encoded reversed-tap plane for the authenticated FIR path.
    DotBatch(DotBatchEncoded),
    /// Pre-encoded RK4 vector-field constants.
    Rk4Coeffs(Vec<Hrfna>),
}

impl CachedOperand {
    /// Approximate heap footprint in bytes — lane buffers plus exponent
    /// and interval sidecars. Container headers are ignored; the budget
    /// is a working-set bound, not an allocator ledger.
    pub fn approx_bytes(&self) -> usize {
        match self {
            CachedOperand::Batch(b) => b.len() * (b.k() * 8 + 20),
            CachedOperand::Taps(ts) => {
                let k = ts.first().map_or(0, |h| h.r.r.len());
                ts.len() * (k * 8 + 20)
            }
            CachedOperand::DotBatch(d) => {
                d.plane.k() * d.plane.n() * 8 + d.f.len() * 4
            }
            CachedOperand::Rk4Coeffs(ts) => {
                let k = ts.first().map_or(0, |h| h.r.r.len());
                ts.len() * (k * 8 + 20)
            }
        }
    }
}

/// Outcome of one [`OpCache::get_or_insert_with`] call, for metrics
/// attribution at the call site.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Lookup {
    /// The value was served from the cache (no build ran).
    pub hit: bool,
    /// Entries evicted to fit the inserted value.
    pub evictions: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Key {
    digest: u64,
    tier: Tier,
    authenticated: bool,
    /// Auth-key epoch the entry was inserted under; always 0 for
    /// unauthenticated entries. Bumping the epoch makes old
    /// authenticated keys unreachable (then sweeps them).
    epoch: u64,
}

struct Entry {
    value: Arc<CachedOperand>,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    map: HashMap<Key, Entry>,
    tick: u64,
    total_bytes: usize,
}

/// Byte-bounded LRU cache of encoded operands, shared by all workers of
/// a coordinator. See the module docs for the keying and invalidation
/// contract.
pub struct OpCache {
    capacity_bytes: usize,
    auth_epoch: AtomicU64,
    inner: Mutex<Inner>,
}

impl OpCache {
    /// New cache holding at most `capacity_bytes` of encoded operands
    /// (approximate accounting, see [`CachedOperand::approx_bytes`]).
    pub fn new(capacity_bytes: usize) -> OpCache {
        OpCache {
            capacity_bytes,
            auth_epoch: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
                total_bytes: 0,
            }),
        }
    }

    /// Byte budget the cache was built with.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// Current number of cached entries.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when no entries are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current approximate resident bytes.
    pub fn total_bytes(&self) -> usize {
        self.inner.lock().unwrap().total_bytes
    }

    /// Current authenticated-entry epoch.
    pub fn auth_epoch(&self) -> u64 {
        self.auth_epoch.load(Ordering::Relaxed)
    }

    /// Look up the operand for `(digest, tier, authenticated)`, building
    /// and inserting it on a miss. The build closure runs *outside* the
    /// cache lock, so a slow block encode never stalls other workers'
    /// lookups; if another worker inserted the same key meanwhile, its
    /// copy wins (keeping one shared plane) and this call still reports
    /// a miss, because it paid for the encode.
    ///
    /// Values larger than the whole cache budget are returned uncached.
    pub fn get_or_insert_with(
        &self,
        digest: u64,
        tier: Tier,
        authenticated: bool,
        build: impl FnOnce() -> CachedOperand,
    ) -> (Arc<CachedOperand>, Lookup) {
        let epoch = if authenticated { self.auth_epoch() } else { 0 };
        let key = Key {
            digest,
            tier,
            authenticated,
            epoch,
        };
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(&key) {
                e.last_used = tick;
                return (
                    Arc::clone(&e.value),
                    Lookup {
                        hit: true,
                        evictions: 0,
                    },
                );
            }
        }

        let value = Arc::new(build());
        let bytes = value.approx_bytes();
        if bytes > self.capacity_bytes {
            return (
                value,
                Lookup {
                    hit: false,
                    evictions: 0,
                },
            );
        }

        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            // Lost the build race: reuse the resident plane so all
            // workers share one copy, but report the miss we paid for.
            e.last_used = tick;
            return (
                Arc::clone(&e.value),
                Lookup {
                    hit: false,
                    evictions: 0,
                },
            );
        }
        let mut evictions = 0u64;
        while inner.total_bytes + bytes > self.capacity_bytes && !inner.map.is_empty() {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("non-empty map has an LRU entry");
            if let Some(e) = inner.map.remove(&victim) {
                inner.total_bytes -= e.bytes;
                evictions += 1;
            }
        }
        inner.total_bytes += bytes;
        inner.map.insert(
            key,
            Entry {
                value: Arc::clone(&value),
                bytes,
                last_used: tick,
            },
        );
        (
            value,
            Lookup {
                hit: false,
                evictions,
            },
        )
    }

    /// Drop every cached entry (and bump the auth epoch). The hook for
    /// events that change what an encode would produce or whether old
    /// planes should be trusted — e.g. rebuilding the tier registry with
    /// different contexts, or recovering a quarantined worker pool.
    pub fn invalidate_all(&self) {
        self.auth_epoch.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        inner.map.clear();
        inner.total_bytes = 0;
    }

    /// Advance the authenticated-entry epoch and sweep every
    /// authenticated entry; unauthenticated entries are untouched. Call
    /// on auth-key rotation.
    pub fn bump_auth_epoch(&self) {
        self.auth_epoch.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let mut freed = 0usize;
        inner.map.retain(|k, e| {
            if k.authenticated {
                freed += e.bytes;
                false
            } else {
                true
            }
        });
        inner.total_bytes -= freed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hybrid::HrfnaContext;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    fn batch_operand(vals: &[f64], ctx: &HrfnaContext) -> CachedOperand {
        CachedOperand::Batch(HrfnaBatch::encode(vals, ctx))
    }

    #[test]
    fn miss_then_hit_shares_one_arc() {
        let ctx = ctx();
        let cache = OpCache::new(1 << 20);
        let (v1, l1) = cache.get_or_insert_with(7, Tier::Paper, false, || {
            batch_operand(&[1.0, 2.0, 3.0], &ctx)
        });
        assert!(!l1.hit);
        let (v2, l2) = cache.get_or_insert_with(7, Tier::Paper, false, || {
            panic!("hit must not rebuild")
        });
        assert!(l2.hit);
        assert!(Arc::ptr_eq(&v1, &v2));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn tier_and_auth_flag_partition_the_key_space() {
        let ctx = ctx();
        let cache = OpCache::new(1 << 20);
        let build = || batch_operand(&[4.0; 8], &ctx);
        let (_, a) = cache.get_or_insert_with(9, Tier::Lo, false, build);
        let (_, b) = cache.get_or_insert_with(9, Tier::Paper, false, build);
        let (_, c) = cache.get_or_insert_with(9, Tier::Paper, true, build);
        assert!(!a.hit && !b.hit && !c.hit);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let ctx = ctx();
        let one = batch_operand(&[1.0; 64], &ctx).approx_bytes();
        // Room for exactly two entries.
        let cache = OpCache::new(2 * one + one / 2);
        for d in 0..2u64 {
            cache.get_or_insert_with(d, Tier::Paper, false, || {
                batch_operand(&[d as f64; 64], &ctx)
            });
        }
        // Touch entry 0 so entry 1 is the LRU victim.
        let (_, l) = cache.get_or_insert_with(0, Tier::Paper, false, || {
            panic!("must hit")
        });
        assert!(l.hit);
        let (_, l2) = cache.get_or_insert_with(2, Tier::Paper, false, || {
            batch_operand(&[2.0; 64], &ctx)
        });
        assert_eq!(l2.evictions, 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.total_bytes() <= cache.capacity_bytes());
        // Entry 0 survived; entry 1 was evicted.
        let (_, l0) = cache.get_or_insert_with(0, Tier::Paper, false, || {
            batch_operand(&[0.0; 64], &ctx)
        });
        assert!(l0.hit);
        let (_, l1) = cache.get_or_insert_with(1, Tier::Paper, false, || {
            batch_operand(&[1.0; 64], &ctx)
        });
        assert!(!l1.hit);
    }

    #[test]
    fn oversize_values_bypass_the_cache() {
        let ctx = ctx();
        let cache = OpCache::new(16);
        let (_, l) = cache.get_or_insert_with(3, Tier::Paper, false, || {
            batch_operand(&[1.0; 128], &ctx)
        });
        assert!(!l.hit);
        assert_eq!(l.evictions, 0);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.total_bytes(), 0);
    }

    #[test]
    fn epoch_bump_sweeps_only_authenticated_entries() {
        let ctx = ctx();
        let cache = OpCache::new(1 << 20);
        cache.get_or_insert_with(1, Tier::Paper, false, || batch_operand(&[1.0; 8], &ctx));
        cache.get_or_insert_with(2, Tier::Paper, true, || batch_operand(&[2.0; 8], &ctx));
        assert_eq!(cache.len(), 2);
        cache.bump_auth_epoch();
        assert_eq!(cache.len(), 1);
        // The unauthenticated entry still hits...
        let (_, lu) = cache.get_or_insert_with(1, Tier::Paper, false, || {
            panic!("must hit")
        });
        assert!(lu.hit);
        // ...while the authenticated key re-misses under the new epoch.
        let (_, la) =
            cache.get_or_insert_with(2, Tier::Paper, true, || batch_operand(&[2.0; 8], &ctx));
        assert!(!la.hit);
    }

    #[test]
    fn invalidate_all_clears_everything() {
        let ctx = ctx();
        let cache = OpCache::new(1 << 20);
        cache.get_or_insert_with(1, Tier::Paper, false, || batch_operand(&[1.0; 8], &ctx));
        cache.get_or_insert_with(2, Tier::Wide, true, || batch_operand(&[2.0; 8], &ctx));
        cache.invalidate_all();
        assert!(cache.is_empty());
        assert_eq!(cache.total_bytes(), 0);
        let (_, l) =
            cache.get_or_insert_with(1, Tier::Paper, false, || batch_operand(&[1.0; 8], &ctx));
        assert!(!l.hit, "invalidated entry must not be served");
    }
}
