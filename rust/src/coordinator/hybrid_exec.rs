//! The coordinator's execution bridge: block-exponent encode/decode
//! between reals and residue lanes (Algorithm 1's "f_0 chosen to match
//! initial operands") plus the batched executors the lane workers call.
//!
//! ## The planar serving path (default)
//!
//! An admitted batch of B dot jobs is encoded in **one pass** into a
//! shared channel-major [`ResiduePlane`] of `B·n` elements — no per-job
//! scalar `Hrfna` allocation, no per-job tensors — then each job's result
//! is one contiguous `lane_dot` window per channel and **one** CRT
//! reconstruction (only requested outputs are reconstructed). The batch's
//! precision context comes from the tier registry — lanes are keyed
//! (kind, tier, bucket), so a batch never mixes tiers. Matmul jobs
//! dispatch through the `workloads` planar fast-path hook
//! ([`crate::workloads::matmul::matmul_hrfna_planar`]) and RK4 jobs are
//! integrated lock-step as one [`crate::hybrid::HrfnaBatch`] per state
//! dimension. FP32 lanes still run the AOT engine graphs.
//!
//! ## The scalar reference path
//!
//! [`ExecMode::Scalar`] executes every hybrid job through per-element
//! scalar [`Hrfna`] values (the reference datapath the planar engine is
//! property-tested against). `bench_serve` measures both modes and the CI
//! gate protects the planar speedup.
//!
//! ## Why block exponents are sound
//!
//! For Σ x_i·y_i to be a valid residue-domain sum, every product must
//! share one exponent. A vector is encoded with a *block-common* exponent
//! `f = ⌈log2 max|x|⌉ − sig + 1`: each element becomes
//! `N_i = round(x_i / 2^f)` with `|N_i| ≤ 2^sig`, stored M-complement per
//! channel. The per-channel modular MAC then computes the residues of the
//! signed integer Σ N_i·M_i exactly (|Σ| ≤ n·2^{2·sig} ≪ M/2 for the
//! bucket sizes), and one CRT reconstruction recovers the value at
//! exponent `f_x + f_y` — zero normalizations inside the kernel, matching
//! §VII-E's measured rarity.

use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::metrics::Metrics;
use super::op_cache::{CachedOperand, OpCache};
use super::request::{Job, JobKind, Payload};
use crate::hybrid::auth::{self, AuthKey};
use crate::hybrid::number::{ldexp_staged, pow2, signed_mag_to_f64};
use crate::hybrid::registry::{ContextRegistry, Tier};
use crate::hybrid::{Hrfna, HrfnaContext};
use crate::rns::plane::{self, ResiduePlane};
use crate::rns::ResidueVec;
use crate::runtime::pjrt::Tensor;
use crate::runtime::EngineHandle;
use crate::workloads::dot::dot_product_encoded_scalar;
use crate::workloads::fir::{fir_filter, fir_filter_encoded_taps, fir_filter_scalar};
use crate::workloads::matmul::{encode_matmul_rhs, matmul_hrfna_planar_encoded};
use crate::workloads::rk4::{
    rk4_final_state, rk4_final_states_batch, rk4_final_states_batch_with, Ode, Rk4Coeffs,
};

/// Which datapath the lane workers execute hybrid jobs on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Per-job scalar `Hrfna` reference (encode each element, MAC loop).
    Scalar,
    /// Batched planar lanes (one-pass block encode, lane kernels, bulk
    /// CRT of requested outputs only).
    #[default]
    Planar,
}

impl ExecMode {
    /// Short label for bench records and tables.
    pub fn label(&self) -> &'static str {
        match self {
            ExecMode::Scalar => "scalar",
            ExecMode::Planar => "planar",
        }
    }
}

/// Block-encoded vector: row-major `k × n` residues plus the shared
/// exponent.
#[derive(Clone, Debug)]
pub struct BlockEncoded {
    /// Residue matrix, channel-major: `res[c * n + j]`.
    pub residues: Vec<i64>,
    pub n: usize,
    pub f: i32,
}

/// Stage one block: write `N_i = round(x_i / 2^f)` into `staged` and
/// return the shared exponent `f` (0 for an all-zero block).
fn stage_block(xs: &[f64], sig: i32, staged: &mut [i64]) -> i32 {
    debug_assert_eq!(xs.len(), staged.len());
    let max = xs.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    if max == 0.0 {
        staged.fill(0);
        return 0;
    }
    let e = max.log2().floor() as i32;
    let f = e - sig + 1;
    let scale = pow2(-f); // |f| < 1100 only via extreme operands; staged below
    if scale.is_finite() && scale != 0.0 {
        for (out, &x) in staged.iter_mut().zip(xs) {
            *out = (x * scale).round() as i64;
        }
    } else {
        for (out, &x) in staged.iter_mut().zip(xs) {
            *out = ldexp_staged(x, -f).round() as i64;
        }
    }
    f
}

/// Encode a real vector with one shared exponent (paper Alg. 1 step 1).
pub fn encode_block(xs: &[f64], ctx: &HrfnaContext) -> BlockEncoded {
    let n = xs.len();
    let mut staged = vec![0i64; n];
    let f = stage_block(xs, ctx.cfg.sig_bits as i32, &mut staged);
    // §Perf (three iterations): (1) Barrett reduction instead of hardware
    // division; (2) channel-major *contiguous* writes — scale once into a
    // staging row, then stream each channel's lane sequentially instead of
    // scattering 8 strided writes per element; (3) the lane loop itself is
    // the planar engine's `ResiduePlane::encode_signed` kernel, shared
    // with the batched execution path.
    let residues = ResiduePlane::encode_signed_i64(&staged, &ctx.cfg.moduli, ctx.barrett());
    BlockEncoded { residues, n, f }
}

/// A whole admitted dot batch block-encoded into one shared plane:
/// `plane` holds `B·n` elements channel-major (job `b` occupies the
/// window `[b·n, (b+1)·n)` of every lane), `f[b]` is job `b`'s block
/// exponent.
///
/// `Clone` exists for the operand cache: executors that mutate the
/// encoded plane in place (fault injection) clone the shared cached
/// entry first.
#[derive(Clone)]
pub struct DotBatchEncoded {
    pub plane: ResiduePlane,
    pub f: Vec<i32>,
    pub n: usize,
}

/// One-pass planar encode of `B` same-bucket operand vectors.
pub fn encode_dot_batch(ops: &[&[f64]], n: usize, ctx: &HrfnaContext) -> DotBatchEncoded {
    let b = ops.len();
    let sig = ctx.cfg.sig_bits as i32;
    let mut staged = vec![0i64; b * n];
    let mut f = Vec::with_capacity(b);
    for (j, xs) in ops.iter().enumerate() {
        debug_assert_eq!(xs.len(), n);
        f.push(stage_block(xs, sig, &mut staged[j * n..(j + 1) * n]));
    }
    let plane = ResiduePlane::encode_signed(&staged, &ctx.cfg.moduli, ctx.barrett());
    DotBatchEncoded { plane, f, n }
}

/// Per-job planar dot products over two batch-encoded planes: one
/// contiguous single-fold `lane_dot` window per channel per job, all B·k
/// dot residues collected channel-major, then **one batched** signed CRT
/// pass over them (scratch and per-modulus tables hoisted out of the
/// per-output loop) instead of B independent reconstructions.
pub fn planar_dot_results(
    x: &DotBatchEncoded,
    y: &DotBatchEncoded,
    ctx: &HrfnaContext,
) -> Vec<f64> {
    debug_assert_eq!(x.n, y.n);
    debug_assert_eq!(x.f.len(), y.f.len());
    let n = x.n;
    let b = x.f.len();
    if b == 0 {
        return Vec::new();
    }
    let bars = ctx.barrett();
    // Channel-major k×B block of dot residues, walked lane-by-lane so the
    // operand planes stream contiguously.
    let mut res = vec![0u64; ctx.k() * b];
    for (c, row) in res.chunks_mut(b).enumerate() {
        let xl = x.plane.lane(c);
        let yl = y.plane.lane(c);
        for (j, out) in row.iter_mut().enumerate() {
            *out = plane::lane_dot(bars[c], &xl[j * n..(j + 1) * n], &yl[j * n..(j + 1) * n]);
        }
    }
    ctx.counters
        .reconstructions
        .fetch_add(b as u64, Ordering::Relaxed);
    ctx.crt
        .reconstruct_signed_batch(&res, b)
        .into_iter()
        .enumerate()
        .map(|(j, (neg, mag))| signed_mag_to_f64(neg, &mag, x.f[j] + y.f[j]))
        .collect()
}

/// Decode per-channel dot-product residues (k values) at exponent `f`.
pub fn decode_scalar(residues: &[i64], f: i32, ctx: &HrfnaContext) -> f64 {
    crate::hybrid::HrfnaContext::count(&ctx.counters.reconstructions);
    let rv = ResidueVec {
        r: residues.iter().map(|&r| r as u64).collect(),
    };
    let (neg, mag) = ctx.crt.reconstruct_signed(&rv);
    signed_mag_to_f64(neg, &mag, f)
}

/// Decode a `k × m × n` residue tensor (channel-major) into `m·n` reals at
/// exponent `f` — one batched signed CRT pass reading the `i64` tensor in
/// place (no per-output gather vector).
pub fn decode_matrix(residues: &[i64], mn: usize, f: i32, ctx: &HrfnaContext) -> Vec<f64> {
    let k = ctx.k();
    assert_eq!(residues.len(), k * mn);
    ctx.counters
        .reconstructions
        .fetch_add(mn as u64, Ordering::Relaxed);
    ctx.crt
        .reconstruct_signed_batch_with(mn, |c, j| residues[c * mn + j] as u64)
        .into_iter()
        .map(|(neg, mag)| signed_mag_to_f64(neg, &mag, f))
        .collect()
}

/// Worst-case encode quantization error for a block at exponent `f`:
/// half a unit per element, `2^{f-1}`.
pub fn block_quantum(f: i32) -> f64 {
    pow2(f - 1)
}

// ----------------------------------------------------------------------
// Encoded-operand cache plumbing
// ----------------------------------------------------------------------

// Digest salts separating the cached operand roles: equal raw bytes in
// different roles (e.g. a tap vector that happens to match a flattened
// weight matrix) must never alias one cache entry.
const MATMUL_RHS_SALT: u64 = 0x6D61_746D_756C_2D62; // "matmul-b"
const FIR_TAPS_SALT: u64 = 0x6669_722D_7461_7073; // "fir-taps"
const FIR_AUTH_SALT: u64 = 0x6669_722D_6175_7468; // "fir-auth"
const RK4_COEFF_SALT: u64 = 0x726B_342D_636F_6566; // "rk4-coef"

/// Worker-side view of the coordinator's operand cache: the cache plus
/// the (kind, tier) slot its lookups attribute metrics to. Threaded
/// through the per-kind executors as `Option<&CacheCtx>`; `None`
/// (direct `execute_batch`/`execute_batch_checked` callers, or cache
/// disabled) keeps the exact cold-encode path.
pub(crate) struct CacheCtx<'a> {
    cache: &'a OpCache,
    metrics: Option<&'a Metrics>,
    kind: JobKind,
    tier: Tier,
}

impl CacheCtx<'_> {
    fn lookup(
        &self,
        digest: u64,
        authenticated: bool,
        build: impl FnOnce() -> CachedOperand,
    ) -> Arc<CachedOperand> {
        let (value, outcome) = self
            .cache
            .get_or_insert_with(digest, self.tier, authenticated, build);
        if let Some(m) = self.metrics {
            m.record_cache_lookup(self.kind, self.tier, outcome.hit, outcome.evictions);
        }
        value
    }
}

// ----------------------------------------------------------------------
// Batched lane executors (called by the server's workers)
// ----------------------------------------------------------------------

/// Execute one admitted batch (all jobs share `kind`, `tier` and shape
/// bucket — the lane key guarantees it). Hybrid kinds resolve their
/// precision context from the registry here, exactly once per batch;
/// a tier's context is therefore built lazily by the first batch that
/// needs it, never by FP32 traffic. Returns per-job results aligned
/// with `jobs`.
pub fn execute_batch(
    engine: &EngineHandle,
    registry: &ContextRegistry,
    mode: ExecMode,
    kind: JobKind,
    tier: Tier,
    jobs: &[Job],
) -> Vec<Result<Vec<f64>>> {
    execute_batch_with(engine, registry, mode, kind, tier, jobs, None)
}

fn execute_batch_with(
    engine: &EngineHandle,
    registry: &ContextRegistry,
    mode: ExecMode,
    kind: JobKind,
    tier: Tier,
    jobs: &[Job],
    cc: Option<&CacheCtx>,
) -> Vec<Result<Vec<f64>>> {
    if jobs.is_empty() {
        return Vec::new();
    }
    debug_assert!(
        jobs.iter().all(|j| j.kind == kind && j.tier == tier),
        "lane batches are single-kind, single-tier by construction"
    );
    match kind {
        JobKind::DotHybrid => {
            let ctx = registry.get(tier);
            match mode {
                ExecMode::Planar => exec_dot_hybrid_planar(&ctx, jobs),
                ExecMode::Scalar => jobs
                    .iter()
                    .map(|j| exec_dot_hybrid_scalar(&ctx, j))
                    .collect(),
            }
        }
        JobKind::DotF32 => exec_dot_f32(engine, jobs),
        JobKind::MatmulHybrid => {
            let ctx = registry.get(tier);
            jobs.iter()
                .map(|j| exec_matmul_hybrid(&ctx, mode, j, cc))
                .collect()
        }
        JobKind::MatmulF32 => jobs.iter().map(|j| exec_matmul_f32(engine, j)).collect(),
        JobKind::FirHybrid => {
            let ctx = registry.get(tier);
            jobs.iter()
                .map(|j| exec_fir_hybrid(&ctx, mode, j, cc))
                .collect()
        }
        JobKind::Rk4Hybrid => {
            let ctx = registry.get(tier);
            match mode {
                ExecMode::Planar => exec_rk4_hybrid_planar(&ctx, jobs, cc),
                ExecMode::Scalar => jobs
                    .iter()
                    .map(|j| exec_rk4_hybrid_scalar(&ctx, j))
                    .collect(),
            }
        }
    }
}

fn payload_error<T>() -> Result<T> {
    Err(anyhow::anyhow!("payload/kind mismatch escaped admission"))
}

// ----------------------------------------------------------------------
// Checked (authentication-aware) execution
// ----------------------------------------------------------------------

/// Per-job output of [`execute_batch_checked`]: the delivered values plus
/// the FNV-1a wire checksum ([`auth::values_checksum`]) when the job was
/// authenticated.
#[derive(Clone, Debug)]
pub struct ExecOutput {
    pub values: Vec<f64>,
    pub check: Option<u64>,
}

/// How a checked job failed.
#[derive(Debug)]
pub enum ExecError {
    /// Plain execution failure — logged and delivered as the historical
    /// NaN-valued result, exactly as before authentication existed.
    Job(anyhow::Error),
    /// Authenticated verification failure (MAC/range/exponent-duplicate
    /// mismatch, Freivalds rejection). The values are never delivered;
    /// the server maps this onto the typed
    /// [`super::error::Error::IntegrityFailure`].
    Integrity(String),
}

/// [`execute_batch`] plus end-to-end integrity for authenticated jobs.
///
/// Batches with no authenticated job take the exact pre-existing path
/// (same executors, same bits) with `check: None`. When the batch carries
/// authenticated jobs, a fresh per-batch MAC key is sampled (worker-local
/// — MAC lanes are derived right after encode and verified before decode
/// within this one call, so the key never needs to outlive the batch),
/// dot/FIR jobs run the dual-MAC verified window dots, and matmul jobs
/// get a Freivalds randomized product check; verified values are covered
/// by the wire checksum the router re-computes on receipt.
///
/// Under the `fault-inject` cargo feature (and an installed
/// [`crate::util::faults`] plan) seeded bit flips are driven into the
/// residue lanes, MAC lanes and exponent words of authenticated jobs
/// between MAC derivation and verification — the single-event-upset model
/// the verification layer exists to catch.
pub fn execute_batch_checked(
    engine: &EngineHandle,
    registry: &ContextRegistry,
    mode: ExecMode,
    kind: JobKind,
    tier: Tier,
    jobs: &[Job],
) -> Vec<Result<ExecOutput, ExecError>> {
    execute_batch_cached(engine, registry, mode, kind, tier, jobs, None, None)
}

/// [`execute_batch_checked`] consulting a shared encoded-operand
/// [`OpCache`] for the reusable halves of matmul and FIR jobs (weight
/// matrices, tap vectors). A `None` cache — or any cache miss — takes
/// the exact cold-encode path, so results are bit-identical with and
/// without the cache; `metrics` (when given) receives per-(kind, tier)
/// hit/miss/eviction counts.
#[allow(clippy::too_many_arguments)]
pub fn execute_batch_cached(
    engine: &EngineHandle,
    registry: &ContextRegistry,
    mode: ExecMode,
    kind: JobKind,
    tier: Tier,
    jobs: &[Job],
    cache: Option<&OpCache>,
    metrics: Option<&Metrics>,
) -> Vec<Result<ExecOutput, ExecError>> {
    let cc = cache.map(|cache| CacheCtx {
        cache,
        metrics,
        kind,
        tier,
    });
    execute_batch_checked_with(engine, registry, mode, kind, tier, jobs, cc.as_ref())
}

fn execute_batch_checked_with(
    engine: &EngineHandle,
    registry: &ContextRegistry,
    mode: ExecMode,
    kind: JobKind,
    tier: Tier,
    jobs: &[Job],
    cc: Option<&CacheCtx>,
) -> Vec<Result<ExecOutput, ExecError>> {
    if jobs.is_empty() {
        return Vec::new();
    }
    if !jobs.iter().any(|j| j.auth) {
        return execute_batch_with(engine, registry, mode, kind, tier, jobs, cc)
            .into_iter()
            .map(|r| match r {
                Ok(values) => Ok(ExecOutput { values, check: None }),
                Err(e) => Err(ExecError::Job(e)),
            })
            .collect();
    }
    // Deterministic per-batch key seed: reproducible under a fixed
    // submission order, distinct across batches.
    let key_seed = jobs[0].id ^ 0xA07D_5EED_0BAD_C0DE;
    match kind {
        JobKind::DotHybrid => {
            let ctx = registry.get(tier);
            exec_dot_checked(&ctx, mode, jobs, key_seed)
        }
        JobKind::FirHybrid => {
            let ctx = registry.get(tier);
            jobs.iter()
                .map(|j| exec_fir_checked(&ctx, mode, j, key_seed, cc))
                .collect()
        }
        JobKind::MatmulHybrid => {
            let ctx = registry.get(tier);
            jobs.iter()
                .map(|j| exec_matmul_checked(&ctx, mode, j, cc))
                .collect()
        }
        // Admission rejects `auth` on kinds without MAC-carrying residue
        // lanes; reaching here means a corrupted queue, which is itself
        // an integrity failure.
        JobKind::DotF32 | JobKind::MatmulF32 | JobKind::Rk4Hybrid => jobs
            .iter()
            .map(|_| {
                Err(ExecError::Integrity(
                    "authenticated job on a kind without MAC support escaped admission"
                        .into(),
                ))
            })
            .collect(),
    }
}

/// Authenticated dot batch: one shared planar encode (value windows are
/// bit-identical to the unauthenticated planar path), MAC planes derived
/// per channel, then each authenticated job is one dual-MAC verified
/// window dot plus an exponent-duplicate compare.
fn exec_dot_checked(
    ctx: &HrfnaContext,
    mode: ExecMode,
    jobs: &[Job],
    key_seed: u64,
) -> Vec<Result<ExecOutput, ExecError>> {
    let mut xs: Vec<&[f64]> = Vec::with_capacity(jobs.len());
    let mut ys: Vec<&[f64]> = Vec::with_capacity(jobs.len());
    for job in jobs {
        match &job.payload {
            Payload::Dot { x, y } => {
                xs.push(x);
                ys.push(y);
            }
            _ => {
                return jobs
                    .iter()
                    .map(|_| payload_error().map_err(ExecError::Job))
                    .collect()
            }
        }
    }
    let n = jobs[0].bucket;
    let mut ex = encode_dot_batch(&xs, n, ctx);
    let mut ey = encode_dot_batch(&ys, n, ctx);
    let key = AuthKey::sample(&ctx.cfg.moduli, key_seed);
    let bars = ctx.barrett();
    let mut mac_x = ex.plane.scale_channels(&key.alpha, bars);
    let mut mac_y = ey.plane.scale_channels(&key.alpha, bars);
    // Exponent duplicates, captured at the trust boundary.
    let fx_dup = ex.f.clone();
    let fy_dup = ey.f.clone();
    #[cfg(feature = "fault-inject")]
    inject_dot_faults(jobs, n, &mut ex, &mut ey, &mut mac_x, &mut mac_y);
    let values = planar_dot_results(&ex, &ey, ctx);
    jobs.iter()
        .enumerate()
        .map(|(j, job)| {
            if !job.auth {
                // Unauthenticated rider in a mixed batch: same value the
                // pre-auth path would deliver (scalar mode keeps its
                // scalar reference datapath).
                return match mode {
                    ExecMode::Planar => Ok(ExecOutput {
                        values: vec![values[j]],
                        check: None,
                    }),
                    ExecMode::Scalar => exec_dot_hybrid_scalar(ctx, job)
                        .map(|v| ExecOutput { values: v, check: None })
                        .map_err(ExecError::Job),
                };
            }
            if ex.f[j] != fx_dup[j] || ey.f[j] != fy_dup[j] {
                return Err(ExecError::Integrity(format!(
                    "exponent duplicate mismatch (dot job {})",
                    job.id
                )));
            }
            match auth::verified_window_dot(
                bars, &key, &ex.plane, &mac_x, &ey.plane, &mac_y, j * n, n,
            ) {
                Ok(_) => {
                    let v = vec![values[j]];
                    let check = auth::values_checksum(&v);
                    Ok(ExecOutput { values: v, check: Some(check) })
                }
                Err(c) => Err(ExecError::Integrity(format!(
                    "MAC check failed in channel {c} (dot job {})",
                    job.id
                ))),
            }
        })
        .collect()
}

/// FIR window geometry for output `t` of a direct-form filter with `tt`
/// taps: the reversed-taps suffix `[tt - len, tt)` dotted against the
/// signal window `[t + 1 - len, t + 1)`, `len = min(t + 1, tt)`
/// (zero-padded history ⇒ warmup outputs use partial windows).
fn fir_window(tt: usize, t: usize) -> (usize, usize, usize) {
    let len = (t + 1).min(tt);
    (tt - len, t + 1 - len, len)
}

/// Authenticated FIR: taps (reversed) and signal each block-encoded into
/// one plane with a shared exponent, MAC planes derived, then every
/// output is a dual-MAC verified window dot; one batched CRT pass decodes
/// the verified residues.
fn exec_fir_checked(
    ctx: &HrfnaContext,
    mode: ExecMode,
    job: &Job,
    key_seed: u64,
    cc: Option<&CacheCtx>,
) -> Result<ExecOutput, ExecError> {
    let (taps, x) = match &job.payload {
        Payload::Fir { taps, x } => (taps, x),
        _ => return payload_error().map_err(ExecError::Job),
    };
    if !job.auth {
        return exec_fir_hybrid(ctx, mode, job, cc)
            .map(|values| ExecOutput { values, check: None })
            .map_err(ExecError::Job);
    }
    let key = AuthKey::sample(&ctx.cfg.moduli, key_seed ^ job.id.rotate_left(17));
    let n = x.len();
    let tt = taps.len();
    // The reversed-tap plane is the job-independent half: consult the
    // cache (authenticated partition, so an auth-epoch bump strands it)
    // and **clone** the shared entry — MAC lanes are derived per job
    // from the plane below, and fault injection mutates the per-job
    // copy in place; the cached entry itself is never mutated, so an
    // injected corruption can't poison later jobs.
    let encode_rt = || {
        let rt: Vec<f64> = taps.iter().rev().copied().collect();
        encode_dot_batch(&[&rt], tt, ctx)
    };
    let mut et = match cc {
        Some(cc) => {
            let digest = auth::operand_digest_with(FIR_AUTH_SALT, taps);
            let cached = cc.lookup(digest, true, || CachedOperand::DotBatch(encode_rt()));
            match &*cached {
                CachedOperand::DotBatch(d) => d.clone(),
                // Role salts preclude cross-variant aliasing; if it ever
                // happened, re-encode rather than misuse the entry.
                _ => encode_rt(),
            }
        }
        None => encode_rt(),
    };
    let mut ex = encode_dot_batch(&[x.as_slice()], n, ctx);
    let bars = ctx.barrett();
    let mut mac_t = et.plane.scale_channels(&key.alpha, bars);
    let mut mac_x = ex.plane.scale_channels(&key.alpha, bars);
    let (ft_dup, fx_dup) = (et.f[0], ex.f[0]);
    #[cfg(feature = "fault-inject")]
    {
        inject_plane_faults(&mut et, &mut mac_t);
        inject_plane_faults(&mut ex, &mut mac_x);
    }
    let k = ctx.k();
    let mut res = vec![0u64; k * n];
    for t in 0..n {
        let (tlo, xlo, len) = fir_window(tt, t);
        match auth::verified_window_dot_at(
            bars, &key, &et.plane, &mac_t, &ex.plane, &mac_x, tlo, xlo, len,
        ) {
            Ok(r) => {
                for (c, &rc) in r.iter().enumerate() {
                    res[c * n + t] = rc;
                }
            }
            Err(c) => {
                return Err(ExecError::Integrity(format!(
                    "MAC check failed in channel {c} (fir output {t}, job {})",
                    job.id
                )))
            }
        }
    }
    if et.f[0] != ft_dup || ex.f[0] != fx_dup {
        return Err(ExecError::Integrity(format!(
            "exponent duplicate mismatch (fir job {})",
            job.id
        )));
    }
    ctx.counters
        .reconstructions
        .fetch_add(n as u64, Ordering::Relaxed);
    let f = et.f[0] + ex.f[0];
    let values: Vec<f64> = ctx
        .crt
        .reconstruct_signed_batch(&res, n)
        .into_iter()
        .map(|(neg, mag)| signed_mag_to_f64(neg, &mag, f))
        .collect();
    let check = auth::values_checksum(&values);
    Ok(ExecOutput { values, check: Some(check) })
}

/// Authenticated matmul: the product is computed on the normal datapath,
/// then Freivalds-verified against the inputs (O(dim²) per round vs the
/// O(dim³) product; 2 rounds ⇒ miss ≤ 1/4 for an adversarial wrong
/// product, deterministic for the high-bit fault model whose error dwarfs
/// the tolerance). The tolerance scales with the tier's significand width
/// so legitimate residue-path rounding never trips it.
fn exec_matmul_checked(
    ctx: &HrfnaContext,
    mode: ExecMode,
    job: &Job,
    cc: Option<&CacheCtx>,
) -> Result<ExecOutput, ExecError> {
    if !job.auth {
        return exec_matmul_hybrid(ctx, mode, job, cc)
            .map(|values| ExecOutput { values, check: None })
            .map_err(ExecError::Job);
    }
    let (a, b, dim) = match &job.payload {
        Payload::Matmul { a, b, dim } => (a, b, *dim),
        _ => return payload_error().map_err(ExecError::Job),
    };
    // The product itself may come off a cached encoded RHS — Freivalds
    // verifies the delivered values against the *raw* f64 inputs, so a
    // stale or corrupted cached plane is caught exactly like a faulty
    // datapath would be.
    #[allow(unused_mut)]
    let mut out = match exec_matmul_hybrid(ctx, mode, job, cc) {
        Ok(v) => v,
        Err(e) => return Err(ExecError::Job(e)),
    };
    #[cfg(feature = "fault-inject")]
    if let Some(pick) = crate::util::faults::global().and_then(|inj| inj.draw()) {
        let i = (pick as usize) % out.len();
        out[i] = crate::util::faults::flip_f64_high_bit(out[i], pick >> 8);
    }
    // Freivalds tolerance: encode quantization is ≤ max|·|·2^{-sig} per
    // element, a product row sums dim such terms and the ±1 probe sums
    // dim outputs — dim²·max|a|·max|b|·2^{-sig}, with 3 bits of margin.
    let amax = a.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    let bmax = b.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    let tol = (dim * dim) as f64
        * amax.max(1.0)
        * bmax.max(1.0)
        * pow2(-(ctx.cfg.sig_bits as i32) + 3);
    if !auth::freivalds_matmul_check(a, b, &out, dim, 2, job.id, tol) {
        return Err(ExecError::Integrity(format!(
            "Freivalds check rejected matmul product (dim {dim}, job {})",
            job.id
        )));
    }
    let check = auth::values_checksum(&out);
    Ok(ExecOutput { values: out, check: Some(check) })
}

/// Seeded corruption of an authenticated dot batch: per authenticated
/// job, one opportunity each for a value-lane flip (either operand), a
/// MAC-lane flip, and an exponent-word flip. Bits stay below 31 so a
/// corrupted word still respects the lane kernels' `< 2^31` input domain
/// (out-of-range words are the range check's job and are exercised by the
/// property tests directly).
#[cfg(feature = "fault-inject")]
fn inject_dot_faults(
    jobs: &[Job],
    n: usize,
    ex: &mut DotBatchEncoded,
    ey: &mut DotBatchEncoded,
    mac_x: &mut ResiduePlane,
    mac_y: &mut ResiduePlane,
) {
    use crate::util::faults::{flip_bit, global};
    let Some(inj) = global() else { return };
    let k = mac_x.k();
    for (j, job) in jobs.iter().enumerate() {
        if !job.auth {
            continue;
        }
        if let Some(p) = inj.draw() {
            let chan = (p as usize >> 1) % k;
            let elem = j * n + ((p >> 16) as usize) % n;
            let bit = ((p >> 40) % 31) as u32;
            let lane = if p & 1 == 0 { ex.plane.lane_mut(chan) } else { ey.plane.lane_mut(chan) };
            lane[elem] = flip_bit(lane[elem], bit);
        }
        if let Some(p) = inj.draw() {
            let chan = (p as usize >> 1) % k;
            let elem = j * n + ((p >> 16) as usize) % n;
            let bit = ((p >> 40) % 31) as u32;
            let lane = if p & 1 == 0 { mac_x.lane_mut(chan) } else { mac_y.lane_mut(chan) };
            lane[elem] = flip_bit(lane[elem], bit);
        }
        if let Some(p) = inj.draw() {
            let f = if p & 1 == 0 { &mut ex.f[j] } else { &mut ey.f[j] };
            *f ^= 1i32 << ((p >> 8) % 24);
        }
    }
}

/// Seeded corruption of one encoded operand plane (FIR path): one
/// opportunity for a value/MAC lane flip and one for the shared exponent
/// word.
#[cfg(feature = "fault-inject")]
fn inject_plane_faults(enc: &mut DotBatchEncoded, mac: &mut ResiduePlane) {
    use crate::util::faults::{flip_bit, global};
    let Some(inj) = global() else { return };
    let k = mac.k();
    let n = enc.n;
    if let Some(p) = inj.draw() {
        let chan = (p as usize >> 1) % k;
        let elem = ((p >> 16) as usize) % n;
        let bit = ((p >> 40) % 31) as u32;
        let lane = if p & 1 == 0 { enc.plane.lane_mut(chan) } else { mac.lane_mut(chan) };
        lane[elem] = flip_bit(lane[elem], bit);
    }
    if let Some(p) = inj.draw() {
        enc.f[0] ^= 1i32 << ((p >> 8) % 24);
    }
}

/// Hybrid FIR: the `workloads` direct-form filter in the lane's datapath
/// (planar batched `dot_encoded` windows, or the scalar per-output MAC
/// reference). With a cache, the planar path reuses the encoded tap
/// vector across jobs sharing a filter — bit-identical because the
/// cached taps are the very `N::from_f64` encodes `fir_filter` would
/// produce inline (pinned by `pre_encoded_taps_bit_identical_to_raw_taps`).
fn exec_fir_hybrid(
    ctx: &HrfnaContext,
    mode: ExecMode,
    job: &Job,
    cc: Option<&CacheCtx>,
) -> Result<Vec<f64>> {
    let (taps, x) = match &job.payload {
        Payload::Fir { taps, x } => (taps, x),
        _ => return payload_error(),
    };
    Ok(match mode {
        ExecMode::Planar => {
            if let Some(cc) = cc {
                let digest = auth::operand_digest_with(FIR_TAPS_SALT, taps);
                let cached = cc.lookup(digest, false, || {
                    CachedOperand::Taps(taps.iter().map(|&t| Hrfna::encode(t, ctx)).collect())
                });
                if let CachedOperand::Taps(eh) = &*cached {
                    return Ok(fir_filter_encoded_taps::<Hrfna>(eh, x, ctx));
                }
            }
            fir_filter::<Hrfna>(taps, x, ctx)
        }
        ExecMode::Scalar => fir_filter_scalar::<Hrfna>(taps, x, ctx),
    })
}

/// The planar hot path: every dot job in the batch encoded into one pair
/// of shared planes, one lane-dot window set per job, one CRT per output.
fn exec_dot_hybrid_planar(ctx: &HrfnaContext, jobs: &[Job]) -> Vec<Result<Vec<f64>>> {
    let mut xs: Vec<&[f64]> = Vec::with_capacity(jobs.len());
    let mut ys: Vec<&[f64]> = Vec::with_capacity(jobs.len());
    for job in jobs {
        match &job.payload {
            Payload::Dot { x, y } => {
                xs.push(x);
                ys.push(y);
            }
            _ => return jobs.iter().map(|_| payload_error()).collect(),
        }
    }
    let n = jobs[0].bucket;
    let ex = encode_dot_batch(&xs, n, ctx);
    let ey = encode_dot_batch(&ys, n, ctx);
    planar_dot_results(&ex, &ey, ctx)
        .into_iter()
        .map(|v| Ok(vec![v]))
        .collect()
}

/// The scalar reference path: per-element `Hrfna` encode + the scalar MAC
/// loop (what the planar engine is property-tested against).
fn exec_dot_hybrid_scalar(ctx: &HrfnaContext, job: &Job) -> Result<Vec<f64>> {
    let (x, y) = match &job.payload {
        Payload::Dot { x, y } => (x, y),
        _ => return payload_error(),
    };
    let ex: Vec<Hrfna> = x.iter().map(|&v| Hrfna::encode(v, ctx)).collect();
    let ey: Vec<Hrfna> = y.iter().map(|&v| Hrfna::encode(v, ctx)).collect();
    let acc = dot_product_encoded_scalar::<Hrfna>(&ex, &ey, ctx);
    Ok(vec![acc.decode(ctx)])
}

/// FP32 dots run the AOT engine; the whole batch goes through one
/// `fp32_dot_batch` call when the backend has it (the software executor
/// does), falling back to per-job `fp32_dot` calls otherwise.
fn exec_dot_f32(engine: &EngineHandle, jobs: &[Job]) -> Vec<Result<Vec<f64>>> {
    let n = jobs[0].bucket;
    let b = jobs.len();
    if b > 1 {
        let mut flat_x = Vec::with_capacity(b * n);
        let mut flat_y = Vec::with_capacity(b * n);
        for job in jobs {
            match &job.payload {
                Payload::Dot { x, y } => {
                    flat_x.extend(x.iter().map(|&v| v as f32));
                    flat_y.extend(y.iter().map(|&v| v as f32));
                }
                _ => return jobs.iter().map(|_| payload_error()).collect(),
            }
        }
        // The flats move into the one batched call (no copies on the hot
        // path); the per-job fallback below rebuilds from the payloads.
        let batched = engine.execute(
            "fp32_dot_batch",
            vec![
                Tensor::F32(flat_x, vec![b, n]),
                Tensor::F32(flat_y, vec![b, n]),
            ],
        );
        match batched.and_then(|out| out.into_f32()) {
            Ok(v) if v.len() == b => {
                return v.into_iter().map(|s| Ok(vec![s as f64])).collect()
            }
            // Fall through to per-job graphs (real PJRT manifests only
            // carry the frozen per-job shapes).
            _ => {}
        }
    }
    jobs.iter()
        .map(|job| {
            let (x, y) = match &job.payload {
                Payload::Dot { x, y } => (x, y),
                _ => return payload_error(),
            };
            let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
            let out = engine
                .execute(
                    "fp32_dot",
                    vec![Tensor::F32(xf, vec![n]), Tensor::F32(yf, vec![n])],
                )?
                .into_f32()?;
            Ok(vec![out[0] as f64])
        })
        .collect()
}

/// Hybrid matmul: the `workloads` planar fast-path hook per job (each job
/// already parallelizes across row blocks), or the scalar reference.
/// With a cache, the planar path reuses the transposed block-encoded
/// weight plane across jobs sharing a `B` — bit-identical because the
/// cached plane is the very `encode_matmul_rhs` value the one-shot path
/// constructs inline (pinned by
/// `pre_encoded_rhs_bit_identical_to_one_shot_planar`).
fn exec_matmul_hybrid(
    ctx: &HrfnaContext,
    mode: ExecMode,
    job: &Job,
    cc: Option<&CacheCtx>,
) -> Result<Vec<f64>> {
    let (a, b, dim) = match &job.payload {
        Payload::Matmul { a, b, dim } => (a, b, *dim),
        _ => return payload_error(),
    };
    match mode {
        ExecMode::Planar => {
            if let Some(cc) = cc {
                // The inner dimension rides in the salt so a flattened
                // square B of another dim can't alias the entry.
                let digest = auth::operand_digest_with(MATMUL_RHS_SALT ^ dim as u64, b);
                let cached = cc.lookup(digest, false, || {
                    CachedOperand::Batch(encode_matmul_rhs(b, dim, dim, ctx))
                });
                if let CachedOperand::Batch(eb) = &*cached {
                    return Ok(matmul_hrfna_planar_encoded(a, eb, dim, dim, dim, ctx));
                }
            }
            Ok(crate::workloads::matmul::matmul::<Hrfna>(
                a, b, dim, dim, dim, ctx,
            ))
        }
        ExecMode::Scalar => {
            let ea: Vec<Hrfna> = a.iter().map(|&v| Hrfna::encode(v, ctx)).collect();
            let eb: Vec<Hrfna> = b.iter().map(|&v| Hrfna::encode(v, ctx)).collect();
            let mut out = Vec::with_capacity(dim * dim);
            for i in 0..dim {
                for j in 0..dim {
                    let mut acc = Hrfna::zero(ctx, 0);
                    for p in 0..dim {
                        acc.mac_assign(&ea[i * dim + p], &eb[p * dim + j], ctx);
                    }
                    out.push(acc.decode(ctx));
                }
            }
            Ok(out)
        }
    }
}

fn exec_matmul_f32(engine: &EngineHandle, job: &Job) -> Result<Vec<f64>> {
    let (a, b, dim) = match &job.payload {
        Payload::Matmul { a, b, dim } => (a, b, *dim),
        _ => return payload_error(),
    };
    let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
    let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
    let out = engine
        .execute(
            "fp32_matmul",
            vec![
                Tensor::F32(af, vec![dim, dim]),
                Tensor::F32(bf, vec![dim, dim]),
            ],
        )?
        .into_f32()?;
    Ok(out.into_iter().map(|v| v as f64).collect())
}

/// Planar RK4: jobs sharing (mu, dt, steps) integrate lock-step as one
/// planar batch; only final states are decoded (bulk CRT of requested
/// outputs). Heterogeneous batches degrade gracefully into sub-groups.
/// With a cache, each group's vector-field constant table
/// ([`Rk4Coeffs`]) is served from the operand cache keyed by the ODE's
/// constants — bit-identical to the cold encode because
/// `Rk4Coeffs::encode` is deterministic (pinned by
/// `precomputed_coeffs_bit_identical_to_cold_encode` and the op-cache
/// integration suite).
fn exec_rk4_hybrid_planar(
    ctx: &HrfnaContext,
    jobs: &[Job],
    cc: Option<&CacheCtx>,
) -> Vec<Result<Vec<f64>>> {
    let mut params: Vec<(u64, u64, u64)> = Vec::with_capacity(jobs.len());
    for job in jobs {
        match &job.payload {
            Payload::Rk4 { mu, dt, steps, .. } => {
                params.push((mu.to_bits(), dt.to_bits(), *steps));
            }
            _ => return jobs.iter().map(|_| payload_error()).collect(),
        }
    }
    let mut out: Vec<Option<Result<Vec<f64>>>> = (0..jobs.len()).map(|_| None).collect();
    let mut done = vec![false; jobs.len()];
    for g in 0..jobs.len() {
        if done[g] {
            continue;
        }
        // Gather the group sharing job g's parameters.
        let group: Vec<usize> = (g..jobs.len())
            .filter(|&j| !done[j] && params[j] == params[g])
            .collect();
        let (mu, dt, steps) = match &jobs[g].payload {
            Payload::Rk4 { mu, dt, steps, .. } => (*mu, *dt, *steps),
            _ => unreachable!("checked above"),
        };
        let mut y0s = Vec::with_capacity(group.len());
        for &j in &group {
            if let Payload::Rk4 { y0, .. } = &jobs[j].payload {
                y0s.push(y0.clone());
            }
            done[j] = true;
        }
        let ode = Ode::VanDerPol { mu };
        let finals = match cc {
            Some(cc) => {
                // Keyed by the ODE's constants only — y0/dt/steps don't
                // change what the field encodes.
                let digest = auth::operand_digest_with(RK4_COEFF_SALT, &[mu]);
                let cached = cc.lookup(digest, false, || {
                    CachedOperand::Rk4Coeffs(Rk4Coeffs::encode(&ode, ctx).consts)
                });
                match &*cached {
                    CachedOperand::Rk4Coeffs(consts) => {
                        let coeffs = Rk4Coeffs::from_consts(consts.clone());
                        rk4_final_states_batch_with(&ode, &y0s, dt, steps, &coeffs, ctx)
                    }
                    _ => rk4_final_states_batch(&ode, &y0s, dt, steps, ctx),
                }
            }
            None => rk4_final_states_batch(&ode, &y0s, dt, steps, ctx),
        };
        for (&j, state) in group.iter().zip(finals) {
            out[j] = Some(Ok(state));
        }
    }
    out.into_iter()
        .map(|r| r.unwrap_or_else(payload_error))
        .collect()
}

fn exec_rk4_hybrid_scalar(ctx: &HrfnaContext, job: &Job) -> Result<Vec<f64>> {
    let (y0, mu, dt, steps) = match &job.payload {
        Payload::Rk4 { y0, mu, dt, steps } => (y0, *mu, *dt, *steps),
        _ => return payload_error(),
    };
    Ok(rk4_final_state::<Hrfna>(
        &Ode::VanDerPol { mu },
        y0,
        dt,
        steps,
        ctx,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::workloads::generators::Dist;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    #[test]
    fn roundtrip_single_elements() {
        let c = ctx();
        let xs = [3.75, -1.5e6, 0.001, 42.0];
        let enc = encode_block(&xs, &c);
        let k = c.k();
        for (j, &x) in xs.iter().enumerate() {
            let per: Vec<i64> = (0..k).map(|ch| enc.residues[ch * xs.len() + j]).collect();
            let back = decode_scalar(&per, enc.f, &c);
            // Block-shared exponent: error ≤ half a block quantum.
            assert!(
                (back - x).abs() <= block_quantum(enc.f) * 1.0001,
                "x={x} back={back}"
            );
        }
    }

    #[test]
    fn zero_vector_encodes_zero() {
        let c = ctx();
        let enc = encode_block(&[0.0; 5], &c);
        assert!(enc.residues.iter().all(|&r| r == 0));
        assert_eq!(enc.f, 0);
    }

    #[test]
    fn software_dot_through_residue_math_matches() {
        // Emulate exactly what the engine kernel does (channelwise modular
        // MAC) and check the decoded dot product against f64.
        let c = ctx();
        let xs = [1.5, -2.0, 3.0, 0.25];
        let ys = [2.0, 4.0, -1.0, 8.0];
        let ex = encode_block(&xs, &c);
        let ey = encode_block(&ys, &c);
        let k = c.k();
        let n = xs.len();
        let mut acc = vec![0i64; k];
        for ch in 0..k {
            let m = c.cfg.moduli[ch] as i64;
            for j in 0..n {
                acc[ch] = (acc[ch] + ex.residues[ch * n + j] * ey.residues[ch * n + j]) % m;
            }
        }
        let got = decode_scalar(&acc, ex.f + ey.f, &c);
        let want: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
        assert!(
            ((got - want) / want).abs() < 1e-6,
            "got={got} want={want}"
        );
    }

    #[test]
    fn decode_matrix_layout() {
        let c = ctx();
        let k = c.k();
        // Encode the 2-vector [7, -3] as a "matrix" of 2 elements.
        let enc = encode_block(&[7.0, -3.0], &c);
        let vals = decode_matrix(&enc.residues, 2, enc.f, &c);
        assert!((vals[0] - 7.0).abs() < 1e-6);
        assert!((vals[1] + 3.0).abs() < 1e-6);
        assert_eq!(enc.residues.len(), k * 2);
    }

    #[test]
    fn batch_encode_matches_per_job_encode_block() {
        // The one-pass batch encode must stage exactly what per-job
        // encode_block stages: same exponents, same residues per window.
        let c = ctx();
        let mut rng = Rng::new(5);
        let n = 64;
        let jobs: Vec<Vec<f64>> = (0..5)
            .map(|i| {
                if i == 3 {
                    vec![0.0; n] // all-zero job in the middle of the batch
                } else {
                    Dist::high_dynamic_range().sample_vec(&mut rng, n)
                }
            })
            .collect();
        let slices: Vec<&[f64]> = jobs.iter().map(|v| v.as_slice()).collect();
        let batch = encode_dot_batch(&slices, n, &c);
        let k = c.k();
        for (b, job) in jobs.iter().enumerate() {
            let single = encode_block(job, &c);
            assert_eq!(batch.f[b], single.f, "job {b} exponent");
            for ch in 0..k {
                let lane = &batch.plane.lane(ch)[b * n..(b + 1) * n];
                for j in 0..n {
                    assert_eq!(
                        lane[j] as i64,
                        single.residues[ch * n + j],
                        "job {b} ch {ch} elem {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn planar_dot_results_bit_identical_to_per_output_decode() {
        // The batched-CRT path must reproduce the former per-output
        // decode_scalar results bit for bit (including all-zero jobs).
        let c = ctx();
        let mut rng = Rng::new(23);
        let n = 64;
        let jobs: Vec<Vec<f64>> = (0..5)
            .map(|i| {
                if i == 2 {
                    vec![0.0; n]
                } else {
                    Dist::high_dynamic_range().sample_vec(&mut rng, n)
                }
            })
            .collect();
        let ys: Vec<Vec<f64>> = (0..5)
            .map(|_| Dist::moderate().sample_vec(&mut rng, n))
            .collect();
        let sx: Vec<&[f64]> = jobs.iter().map(|v| v.as_slice()).collect();
        let sy: Vec<&[f64]> = ys.iter().map(|v| v.as_slice()).collect();
        let ex = encode_dot_batch(&sx, n, &c);
        let ey = encode_dot_batch(&sy, n, &c);
        let got = planar_dot_results(&ex, &ey, &c);
        let bars = c.barrett();
        for (j, &g) in got.iter().enumerate() {
            let res: Vec<i64> = (0..c.k())
                .map(|ch| {
                    let xs = &ex.plane.lane(ch)[j * n..(j + 1) * n];
                    let yl = &ey.plane.lane(ch)[j * n..(j + 1) * n];
                    plane::lane_dot(bars[ch], xs, yl) as i64
                })
                .collect();
            let want = decode_scalar(&res, ex.f[j] + ey.f[j], &c);
            assert_eq!(g.to_bits(), want.to_bits(), "job {j}: {g} vs {want}");
        }
    }

    #[test]
    fn planar_dot_results_match_f64() {
        let c = ctx();
        let mut rng = Rng::new(11);
        let n = 512;
        let xs: Vec<Vec<f64>> = (0..4)
            .map(|_| Dist::moderate().sample_vec(&mut rng, n))
            .collect();
        let ys: Vec<Vec<f64>> = (0..4)
            .map(|_| Dist::moderate().sample_vec(&mut rng, n))
            .collect();
        let sx: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        let sy: Vec<&[f64]> = ys.iter().map(|v| v.as_slice()).collect();
        let ex = encode_dot_batch(&sx, n, &c);
        let ey = encode_dot_batch(&sy, n, &c);
        let got = planar_dot_results(&ex, &ey, &c);
        for b in 0..4 {
            let want: f64 = xs[b].iter().zip(&ys[b]).map(|(a, v)| a * v).sum();
            let scale: f64 = xs[b].iter().zip(&ys[b]).map(|(a, v)| (a * v).abs()).sum();
            assert!(
                (got[b] - want).abs() < 1e-7 * scale + 1e-300,
                "job {b}: got={} want={want}",
                got[b]
            );
        }
    }
}
