//! Block-exponent encode/decode bridging reals ↔ residue tensors for the
//! AOT kernels (Algorithm 1's "f_0 chosen to match initial operands").
//!
//! The PJRT kernels operate on residues only; for Σ x_i·y_i to be a valid
//! residue-domain sum, every product must share one exponent. So a vector
//! is encoded with a *block-common* exponent `f = ⌈log2 max|x|⌉ − sig + 1`:
//! each element becomes `N_i = round(x_i / 2^f)` with `|N_i| ≤ 2^sig`,
//! stored M-complement per channel. The kernel's per-channel modular MAC
//! then computes the residues of the signed integer Σ N_i·M_i exactly
//! (|Σ| ≤ n·2^{2·sig} ≪ M/2 for the AOT bucket sizes), and one CRT
//! reconstruction recovers the value at exponent `f_x + f_y` — zero
//! normalizations inside the kernel, matching §VII-E's measured rarity.

use crate::hybrid::number::{ldexp_staged, pow2};
use crate::hybrid::HrfnaContext;
use crate::rns::plane::ResiduePlane;
use crate::rns::ResidueVec;

/// Block-encoded vector: row-major `k × n` residues plus the shared
/// exponent.
#[derive(Clone, Debug)]
pub struct BlockEncoded {
    /// Residue matrix, channel-major: `res[c * n + j]`.
    pub residues: Vec<i64>,
    pub n: usize,
    pub f: i32,
}

/// Encode a real vector with one shared exponent (paper Alg. 1 step 1).
pub fn encode_block(xs: &[f64], ctx: &HrfnaContext) -> BlockEncoded {
    let k = ctx.k();
    let n = xs.len();
    let max = xs.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    if max == 0.0 {
        return BlockEncoded {
            residues: vec![0; k * n],
            n,
            f: 0,
        };
    }
    let sig = ctx.cfg.sig_bits as i32;
    let e = max.log2().floor() as i32;
    let f = e - sig + 1;
    // §Perf (three iterations): (1) Barrett reduction instead of hardware
    // division; (2) channel-major *contiguous* writes — scale once into a
    // staging row, then stream each channel's lane sequentially instead of
    // scattering 8 strided writes per element; (3) the lane loop itself is
    // the planar engine's `ResiduePlane::encode_signed` kernel, shared
    // with the batched execution path.
    let scale = pow2(-f); // |f| < 1100 only via extreme operands; staged below
    let staged: Vec<i64> = if scale.is_finite() && scale != 0.0 {
        xs.iter().map(|&x| (x * scale).round() as i64).collect()
    } else {
        xs.iter()
            .map(|&x| ldexp_staged(x, -f).round() as i64)
            .collect()
    };
    let residues = ResiduePlane::encode_signed_i64(&staged, &ctx.cfg.moduli, ctx.barrett());
    BlockEncoded { residues, n, f }
}

/// Decode per-channel dot-product residues (k values) at exponent `f`.
pub fn decode_scalar(residues: &[i64], f: i32, ctx: &HrfnaContext) -> f64 {
    crate::hybrid::HrfnaContext::count(&ctx.counters.reconstructions);
    let rv = ResidueVec {
        r: residues.iter().map(|&r| r as u64).collect(),
    };
    let (neg, mag) = ctx.crt.reconstruct_signed(&rv);
    let v = ldexp_staged(mag.to_f64(), f);
    if neg {
        -v
    } else {
        v
    }
}

/// Decode a `k × m × n` residue tensor (channel-major) into `m·n` reals at
/// exponent `f`.
pub fn decode_matrix(residues: &[i64], mn: usize, f: i32, ctx: &HrfnaContext) -> Vec<f64> {
    let k = ctx.k();
    assert_eq!(residues.len(), k * mn);
    (0..mn)
        .map(|j| {
            let per_channel: Vec<i64> = (0..k).map(|c| residues[c * mn + j]).collect();
            decode_scalar(&per_channel, f, ctx)
        })
        .collect()
}

/// Worst-case encode quantization error for a block at exponent `f`:
/// half a unit per element, `2^{f-1}`.
pub fn block_quantum(f: i32) -> f64 {
    pow2(f - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    #[test]
    fn roundtrip_single_elements() {
        let c = ctx();
        let xs = [3.75, -1.5e6, 0.001, 42.0];
        let enc = encode_block(&xs, &c);
        let k = c.k();
        for (j, &x) in xs.iter().enumerate() {
            let per: Vec<i64> = (0..k).map(|ch| enc.residues[ch * xs.len() + j]).collect();
            let back = decode_scalar(&per, enc.f, &c);
            // Block-shared exponent: error ≤ half a block quantum.
            assert!(
                (back - x).abs() <= block_quantum(enc.f) * 1.0001,
                "x={x} back={back}"
            );
        }
    }

    #[test]
    fn zero_vector_encodes_zero() {
        let c = ctx();
        let enc = encode_block(&[0.0; 5], &c);
        assert!(enc.residues.iter().all(|&r| r == 0));
        assert_eq!(enc.f, 0);
    }

    #[test]
    fn software_dot_through_residue_math_matches() {
        // Emulate exactly what the PJRT kernel does (channelwise modular
        // MAC) and check the decoded dot product against f64.
        let c = ctx();
        let xs = [1.5, -2.0, 3.0, 0.25];
        let ys = [2.0, 4.0, -1.0, 8.0];
        let ex = encode_block(&xs, &c);
        let ey = encode_block(&ys, &c);
        let k = c.k();
        let n = xs.len();
        let mut acc = vec![0i64; k];
        for ch in 0..k {
            let m = c.cfg.moduli[ch] as i64;
            for j in 0..n {
                acc[ch] = (acc[ch] + ex.residues[ch * n + j] * ey.residues[ch * n + j]) % m;
            }
        }
        let got = decode_scalar(&acc, ex.f + ey.f, &c);
        let want: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
        assert!(
            ((got - want) / want).abs() < 1e-6,
            "got={got} want={want}"
        );
    }

    #[test]
    fn decode_matrix_layout() {
        let c = ctx();
        let k = c.k();
        // Encode the 2-vector [7, -3] as a "matrix" of 2 elements.
        let enc = encode_block(&[7.0, -3.0], &c);
        let vals = decode_matrix(&enc.residues, 2, enc.f, &c);
        assert!((vals[0] - 7.0).abs() < 1e-6);
        assert!((vals[1] + 3.0).abs() < 1e-6);
        assert_eq!(enc.residues.len(), k * 2);
    }
}
