//! Request/response types for the coordinator.

use std::time::Instant;

/// Which backend lane a job runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum JobKind {
    /// Dot product in HRFNA through the residue-domain PJRT kernel.
    DotHybrid,
    /// Dot product in FP32 through the baseline PJRT graph.
    DotF32,
    /// Dense matmul in HRFNA.
    MatmulHybrid,
    /// Dense matmul in FP32.
    MatmulF32,
}

impl JobKind {
    /// All kinds (for metrics tables).
    pub const ALL: [JobKind; 4] = [
        JobKind::DotHybrid,
        JobKind::DotF32,
        JobKind::MatmulHybrid,
        JobKind::MatmulF32,
    ];

    /// Table label.
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::DotHybrid => "dot/hrfna",
            JobKind::DotF32 => "dot/fp32",
            JobKind::MatmulHybrid => "matmul/hrfna",
            JobKind::MatmulF32 => "matmul/fp32",
        }
    }
}

/// Job payload (shapes are validated against the AOT bucket at submit).
#[derive(Clone, Debug)]
pub enum Payload {
    /// Dot product of two equal-length vectors (≤ the AOT bucket size).
    Dot { x: Vec<f64>, y: Vec<f64> },
    /// Square matmul at the AOT dimension.
    Matmul { a: Vec<f64>, b: Vec<f64>, dim: usize },
}

impl Payload {
    /// Element count (for throughput metrics).
    pub fn macs(&self) -> u64 {
        match self {
            Payload::Dot { x, .. } => x.len() as u64,
            Payload::Matmul { dim, .. } => (dim * dim * dim) as u64,
        }
    }
}

/// A queued job.
#[derive(Debug)]
pub struct Job {
    pub id: u64,
    pub kind: JobKind,
    pub payload: Payload,
    pub submitted: Instant,
    /// Completion channel.
    pub reply: std::sync::mpsc::Sender<JobResult>,
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub kind: JobKind,
    /// Scalar for dot, row-major matrix for matmul.
    pub values: Vec<f64>,
    /// End-to-end latency in microseconds.
    pub latency_us: f64,
    /// Size of the batch this job was executed in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_macs() {
        let d = Payload::Dot { x: vec![0.0; 7], y: vec![0.0; 7] };
        assert_eq!(d.macs(), 7);
        let m = Payload::Matmul { a: vec![], b: vec![], dim: 4 };
        assert_eq!(m.macs(), 64);
    }

    #[test]
    fn labels_unique() {
        let mut labels: Vec<_> = JobKind::ALL.iter().map(|k| k.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 4);
    }
}
