//! Request/response types for the coordinator: job kinds, payloads, and
//! the [`JobSpec`] builder every submission path starts from. (The typed
//! submission/backpressure errors live in [`super::error`].)
//!
//! Every job routes to a **(kind, tier, shape-bucket)** lane: `kind`
//! selects the datapath, [`Tier`] the precision context the hybrid lanes
//! execute under (resolved — possibly escalated — at admission from the
//! payload's magnitude envelope and the request's tolerance), and the
//! bucket the frozen shape. Batches are single-tier by construction.

use std::time::Instant;

use crate::hybrid::registry::{MagnitudeEnvelope, Tier};
use crate::workloads::rk4::RK4_MACS_PER_STEP;

/// Which backend lane a job runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum JobKind {
    /// Dot product on the planar HRFNA residue lanes.
    DotHybrid,
    /// Dot product in FP32 through the baseline engine graph.
    DotF32,
    /// Dense matmul in HRFNA.
    MatmulHybrid,
    /// Dense matmul in FP32.
    MatmulF32,
    /// Batched RK4 integration (Van der Pol) in HRFNA.
    Rk4Hybrid,
    /// FIR filtering (direct-form inner products) in HRFNA.
    FirHybrid,
}

impl JobKind {
    /// All kinds (for metrics tables).
    pub const ALL: [JobKind; 6] = [
        JobKind::DotHybrid,
        JobKind::DotF32,
        JobKind::MatmulHybrid,
        JobKind::MatmulF32,
        JobKind::Rk4Hybrid,
        JobKind::FirHybrid,
    ];

    /// Table label — also the **wire identifier** of the kind: the RPC
    /// protocol (`coordinator::rpc`) serializes `JobKind` as this string,
    /// so the labels are a stable contract (golden-fixture tested), not
    /// just display strings.
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::DotHybrid => "dot/hrfna",
            JobKind::DotF32 => "dot/fp32",
            JobKind::MatmulHybrid => "matmul/hrfna",
            JobKind::MatmulF32 => "matmul/fp32",
            JobKind::Rk4Hybrid => "rk4/hrfna",
            JobKind::FirHybrid => "fir/hrfna",
        }
    }

    /// Parse a label produced by [`JobKind::label`] (wire decode).
    pub fn from_label(s: &str) -> Option<JobKind> {
        JobKind::ALL.iter().copied().find(|k| k.label() == s)
    }

    /// True iff the kind executes on the HRFNA datapath (and therefore
    /// resolves a precision tier; FP32 lanes are tier-agnostic and pin
    /// to the [`Tier::Paper`] lane slot).
    pub fn is_hybrid(&self) -> bool {
        matches!(
            self,
            JobKind::DotHybrid
                | JobKind::MatmulHybrid
                | JobKind::Rk4Hybrid
                | JobKind::FirHybrid
        )
    }
}

/// Job payload (shapes are validated against the AOT bucket at submit).
#[derive(Clone, Debug)]
pub enum Payload {
    /// Dot product of two equal-length vectors (≤ the largest bucket).
    Dot { x: Vec<f64>, y: Vec<f64> },
    /// Square matmul at the AOT dimension.
    Matmul { a: Vec<f64>, b: Vec<f64>, dim: usize },
    /// RK4-integrate one Van der Pol instance for `steps` steps of `dt`;
    /// the result is the final state. Jobs sharing (mu, dt, steps) are
    /// integrated lock-step as one planar batch.
    Rk4 { y0: Vec<f64>, mu: f64, dt: f64, steps: u64 },
    /// Direct-form FIR filter: convolve signal `x` with `taps`, yielding
    /// `x.len()` outputs (zero-padded history), each an exact taps-length
    /// residue-domain inner product.
    Fir { taps: Vec<f64>, x: Vec<f64> },
}

impl Payload {
    /// MAC-equivalent count (for throughput metrics). RK4 charges
    /// [`RK4_MACS_PER_STEP`] per step — the same constant the §V
    /// hardware timing model uses.
    pub fn macs(&self) -> u64 {
        match self {
            Payload::Dot { x, .. } => x.len() as u64,
            Payload::Matmul { dim, .. } => (dim * dim * dim) as u64,
            Payload::Rk4 { steps, .. } => steps * RK4_MACS_PER_STEP,
            Payload::Fir { taps, x } => (taps.len() * x.len()) as u64,
        }
    }

    /// The payload's magnitude envelope — what tier resolution inspects
    /// *before* any encoding happens: extreme operand magnitude, the
    /// longest exact accumulation, and a coarse a-priori normalization-
    /// event estimate (0 for the zero-mid-loop-rounding planar kernels;
    /// one per step for the iterative ODE workload).
    pub fn envelope(&self) -> MagnitudeEnvelope {
        match self {
            Payload::Dot { x, y } => {
                MagnitudeEnvelope::of_slices(&[x, y], x.len() as u64, 0)
            }
            Payload::Matmul { a, b, dim } => {
                MagnitudeEnvelope::of_slices(&[a, b], *dim as u64, 0)
            }
            Payload::Rk4 { y0, mu, steps, .. } => {
                let max_abs = y0
                    .iter()
                    .fold(mu.abs(), |acc, &v| acc.max(v.abs()));
                MagnitudeEnvelope {
                    max_abs,
                    terms: 4, // k1 + 2k2 + 2k3 + k4 state update
                    norm_events: *steps,
                }
            }
            Payload::Fir { taps, x } => {
                // Each output is one exact taps-length inner product.
                MagnitudeEnvelope::of_slices(&[taps, x], taps.len() as u64, 0)
            }
        }
    }
}

/// A full submission: payload + lane kind + the *requested* precision
/// tier and an optional relative-error tolerance. Admission resolves the
/// actual tier (escalating past `tier` when its formal bound cannot
/// cover the envelope/tolerance — counted in the coordinator metrics).
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub kind: JobKind,
    pub payload: Payload,
    /// Cheapest tier the client is willing to run on.
    pub tier: Tier,
    /// Target relative error; `None` accepts the tier's native budget.
    pub tolerance: Option<f64>,
    /// Request end-to-end integrity: the worker carries MAC residue
    /// lanes through the computation, verifies them before decode, and
    /// checksums the result frame; the router re-verifies and resubmits
    /// on failure. Admission charges the MAC modulus budget
    /// ([`crate::hybrid::registry::EscalateReason::MacBudget`]).
    pub auth: bool,
}

impl JobSpec {
    /// A paper-tier spec with no tolerance — the historical single-
    /// context submission, bit-identical through the registry. The
    /// kind-specific builders below cover the common payloads; use this
    /// constructor when the kind is data-driven.
    pub fn new(kind: JobKind, payload: Payload) -> JobSpec {
        JobSpec { kind, payload, tier: Tier::Paper, tolerance: None, auth: false }
    }

    /// Dot product on the planar HRFNA lanes:
    /// `JobSpec::dot(x, y).tier(Tier::Wide).tolerance(1e-9)`.
    pub fn dot(x: Vec<f64>, y: Vec<f64>) -> JobSpec {
        JobSpec::new(JobKind::DotHybrid, Payload::Dot { x, y })
    }

    /// Dot product on the FP32 baseline lane (tier-agnostic).
    pub fn dot_f32(x: Vec<f64>, y: Vec<f64>) -> JobSpec {
        JobSpec::new(JobKind::DotF32, Payload::Dot { x, y })
    }

    /// Square matmul in HRFNA at the AOT dimension.
    pub fn matmul(a: Vec<f64>, b: Vec<f64>, dim: usize) -> JobSpec {
        JobSpec::new(JobKind::MatmulHybrid, Payload::Matmul { a, b, dim })
    }

    /// Square matmul on the FP32 baseline lane.
    pub fn matmul_f32(a: Vec<f64>, b: Vec<f64>, dim: usize) -> JobSpec {
        JobSpec::new(JobKind::MatmulF32, Payload::Matmul { a, b, dim })
    }

    /// Batched RK4 Van der Pol integration in HRFNA.
    pub fn rk4(y0: Vec<f64>, mu: f64, dt: f64, steps: u64) -> JobSpec {
        JobSpec::new(JobKind::Rk4Hybrid, Payload::Rk4 { y0, mu, dt, steps })
    }

    /// Direct-form FIR filtering in HRFNA.
    pub fn fir(taps: Vec<f64>, x: Vec<f64>) -> JobSpec {
        JobSpec::new(JobKind::FirHybrid, Payload::Fir { taps, x })
    }

    /// Set the cheapest tier the client is willing to run on (admission
    /// may still escalate past it).
    pub fn tier(mut self, tier: Tier) -> JobSpec {
        self.tier = tier;
        self
    }

    /// Set the target relative-error tolerance.
    pub fn tolerance(mut self, tol: f64) -> JobSpec {
        self.tolerance = Some(tol);
        self
    }

    /// Request MAC-authenticated execution and result verification.
    pub fn authenticated(mut self) -> JobSpec {
        self.auth = true;
        self
    }

    /// Pre-PR7 name of [`JobSpec::tier`].
    #[deprecated(note = "renamed to JobSpec::tier")]
    pub fn with_tier(self, tier: Tier) -> JobSpec {
        self.tier(tier)
    }

    /// Pre-PR7 name of [`JobSpec::tolerance`].
    #[deprecated(note = "renamed to JobSpec::tolerance")]
    pub fn with_tolerance(self, tol: f64) -> JobSpec {
        self.tolerance(tol)
    }
}

/// A queued job.
#[derive(Debug)]
pub struct Job {
    pub id: u64,
    pub kind: JobKind,
    pub payload: Payload,
    /// Resolved precision tier (lane routing key; `Paper` on FP32 lanes).
    pub tier: Tier,
    /// Shape bucket the payload was admitted into (queue routing key).
    pub bucket: usize,
    /// MAC-authenticated execution requested at submit.
    pub auth: bool,
    pub submitted: Instant,
    /// Completion channel. Integrity failures travel typed (`Err`);
    /// plain execution errors keep the historical NaN-valued `Ok` form.
    pub reply: std::sync::mpsc::Sender<Result<JobResult, super::error::Error>>,
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub kind: JobKind,
    /// The tier the job actually executed under.
    pub tier: Tier,
    /// Scalar for dot, row-major matrix for matmul, final state for RK4.
    pub values: Vec<f64>,
    /// End-to-end latency in microseconds.
    pub latency_us: f64,
    /// Size of the batch this job was executed in.
    pub batch_size: usize,
    /// FNV-1a checksum over the canonical bits of `values`, present iff
    /// the job was authenticated — the wire-integrity cover for the
    /// result frame (`hybrid::auth::values_checksum`).
    pub check: Option<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_macs() {
        let d = Payload::Dot { x: vec![0.0; 7], y: vec![0.0; 7] };
        assert_eq!(d.macs(), 7);
        let m = Payload::Matmul { a: vec![], b: vec![], dim: 4 };
        assert_eq!(m.macs(), 64);
        let r = Payload::Rk4 { y0: vec![2.0, 0.0], mu: 1.0, dt: 0.01, steps: 10 };
        assert_eq!(r.macs(), 10 * RK4_MACS_PER_STEP);
        // The serving metric and the §V hardware model share the per-step
        // constant — they cannot drift apart.
        assert_eq!(
            r.macs(),
            crate::fpga::pipeline::WorkloadKind::Rk4 { steps: 10 }.macs()
        );
    }

    #[test]
    fn labels_unique() {
        let mut labels: Vec<_> = JobKind::ALL.iter().map(|k| k.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), JobKind::ALL.len());
    }

    #[test]
    fn labels_round_trip() {
        for k in JobKind::ALL {
            assert_eq!(JobKind::from_label(k.label()), Some(k));
        }
        assert_eq!(JobKind::from_label("dot"), None);
    }

    #[test]
    fn hybrid_kind_partition() {
        let hybrid: Vec<_> = JobKind::ALL.iter().filter(|k| k.is_hybrid()).collect();
        assert_eq!(hybrid.len(), 4);
        assert!(JobKind::FirHybrid.is_hybrid());
        assert!(!JobKind::DotF32.is_hybrid());
        assert!(!JobKind::MatmulF32.is_hybrid());
    }

    #[test]
    fn payload_envelopes() {
        let d = Payload::Dot { x: vec![1.0, -8.0], y: vec![0.5, 2.0] };
        let e = d.envelope();
        assert_eq!(e.max_abs, 8.0);
        assert_eq!(e.terms, 2);
        assert_eq!(e.norm_events, 0);
        let m = Payload::Matmul { a: vec![3.0; 4], b: vec![-4.0; 4], dim: 2 };
        let e = m.envelope();
        assert_eq!(e.max_abs, 4.0);
        assert_eq!(e.terms, 2);
        let r = Payload::Rk4 { y0: vec![2.0, 0.0], mu: 5.0, dt: 0.01, steps: 100 };
        let e = r.envelope();
        assert_eq!(e.max_abs, 5.0);
        assert_eq!(e.norm_events, 100);
        let f = Payload::Fir { taps: vec![0.25, 0.5, 0.25], x: vec![-6.0; 16] };
        let e = f.envelope();
        assert_eq!(e.max_abs, 6.0);
        assert_eq!(e.terms, 3, "each FIR output is a taps-length dot");
        assert_eq!(e.norm_events, 0);
        assert_eq!(f.macs(), 48);
    }

    #[test]
    fn spec_builder_defaults_to_paper() {
        let s = JobSpec::dot(vec![1.0], vec![1.0]);
        assert_eq!(s.kind, JobKind::DotHybrid);
        assert_eq!(s.tier, Tier::Paper);
        assert!(s.tolerance.is_none());
        let s = s.tier(Tier::Lo).tolerance(1e-9);
        assert_eq!(s.tier, Tier::Lo);
        assert_eq!(s.tolerance, Some(1e-9));
    }

    #[test]
    fn kind_builders_pick_the_right_lane() {
        assert_eq!(JobSpec::dot_f32(vec![1.0], vec![1.0]).kind, JobKind::DotF32);
        assert_eq!(JobSpec::matmul(vec![1.0; 4], vec![1.0; 4], 2).kind, JobKind::MatmulHybrid);
        assert_eq!(JobSpec::matmul_f32(vec![1.0; 4], vec![1.0; 4], 2).kind, JobKind::MatmulF32);
        let r = JobSpec::rk4(vec![2.0, 0.0], 1.0, 0.01, 100);
        assert_eq!(r.kind, JobKind::Rk4Hybrid);
        match r.payload {
            Payload::Rk4 { steps, .. } => assert_eq!(steps, 100),
            other => panic!("wrong payload {other:?}"),
        }
        let f = JobSpec::fir(vec![0.5, 0.5], vec![1.0; 8]);
        assert_eq!(f.kind, JobKind::FirHybrid);
        assert!(!f.auth, "authentication is opt-in");
        assert!(f.authenticated().auth);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_with_setters_still_work() {
        let s = JobSpec::dot(vec![1.0], vec![1.0])
            .with_tier(Tier::Wide)
            .with_tolerance(1e-7);
        assert_eq!(s.tier, Tier::Wide);
        assert_eq!(s.tolerance, Some(1e-7));
    }
}
