//! Request/response types for the coordinator, plus the typed submission
//! errors that carry the serving layer's backpressure contract.

use std::time::Instant;
use thiserror::Error;

/// Which backend lane a job runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum JobKind {
    /// Dot product on the planar HRFNA residue lanes.
    DotHybrid,
    /// Dot product in FP32 through the baseline engine graph.
    DotF32,
    /// Dense matmul in HRFNA.
    MatmulHybrid,
    /// Dense matmul in FP32.
    MatmulF32,
    /// Batched RK4 integration (Van der Pol) in HRFNA.
    Rk4Hybrid,
}

impl JobKind {
    /// All kinds (for metrics tables).
    pub const ALL: [JobKind; 5] = [
        JobKind::DotHybrid,
        JobKind::DotF32,
        JobKind::MatmulHybrid,
        JobKind::MatmulF32,
        JobKind::Rk4Hybrid,
    ];

    /// Table label.
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::DotHybrid => "dot/hrfna",
            JobKind::DotF32 => "dot/fp32",
            JobKind::MatmulHybrid => "matmul/hrfna",
            JobKind::MatmulF32 => "matmul/fp32",
            JobKind::Rk4Hybrid => "rk4/hrfna",
        }
    }
}

/// Job payload (shapes are validated against the AOT bucket at submit).
#[derive(Clone, Debug)]
pub enum Payload {
    /// Dot product of two equal-length vectors (≤ the largest bucket).
    Dot { x: Vec<f64>, y: Vec<f64> },
    /// Square matmul at the AOT dimension.
    Matmul { a: Vec<f64>, b: Vec<f64>, dim: usize },
    /// RK4-integrate one Van der Pol instance for `steps` steps of `dt`;
    /// the result is the final state. Jobs sharing (mu, dt, steps) are
    /// integrated lock-step as one planar batch.
    Rk4 { y0: Vec<f64>, mu: f64, dt: f64, steps: u64 },
}

impl Payload {
    /// MAC-equivalent count (for throughput metrics). RK4 charges the
    /// ~30 format ops one Van der Pol step costs per instance.
    pub fn macs(&self) -> u64 {
        match self {
            Payload::Dot { x, .. } => x.len() as u64,
            Payload::Matmul { dim, .. } => (dim * dim * dim) as u64,
            Payload::Rk4 { steps, .. } => steps * 30,
        }
    }
}

/// Typed submission failure: the coordinator's admission and backpressure
/// contract. `Overloaded` is the load-shedding signal — callers retry with
/// backoff or divert; the queue never grows without bound.
#[derive(Debug, Error)]
pub enum SubmitError {
    /// The payload failed shape/value admission for its lane.
    #[error("admission rejected: {0}")]
    Rejected(String),
    /// Every shard of the lane's bounded queue is at capacity.
    #[error("lane {kind:?} overloaded: {queued} jobs queued at capacity {capacity}")]
    Overloaded {
        kind: JobKind,
        queued: usize,
        capacity: usize,
    },
    /// The coordinator is draining; no new work is accepted.
    #[error("coordinator is shutting down")]
    ShuttingDown,
}

/// A queued job.
#[derive(Debug)]
pub struct Job {
    pub id: u64,
    pub kind: JobKind,
    pub payload: Payload,
    /// Shape bucket the payload was admitted into (queue routing key).
    pub bucket: usize,
    pub submitted: Instant,
    /// Completion channel.
    pub reply: std::sync::mpsc::Sender<JobResult>,
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub kind: JobKind,
    /// Scalar for dot, row-major matrix for matmul, final state for RK4.
    pub values: Vec<f64>,
    /// End-to-end latency in microseconds.
    pub latency_us: f64,
    /// Size of the batch this job was executed in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_macs() {
        let d = Payload::Dot { x: vec![0.0; 7], y: vec![0.0; 7] };
        assert_eq!(d.macs(), 7);
        let m = Payload::Matmul { a: vec![], b: vec![], dim: 4 };
        assert_eq!(m.macs(), 64);
        let r = Payload::Rk4 { y0: vec![2.0, 0.0], mu: 1.0, dt: 0.01, steps: 10 };
        assert_eq!(r.macs(), 300);
    }

    #[test]
    fn labels_unique() {
        let mut labels: Vec<_> = JobKind::ALL.iter().map(|k| k.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), JobKind::ALL.len());
    }

    #[test]
    fn submit_error_messages_are_typed() {
        let e = SubmitError::Overloaded { kind: JobKind::DotHybrid, queued: 9, capacity: 8 };
        assert!(e.to_string().contains("overloaded"));
        assert!(matches!(e, SubmitError::Overloaded { queued: 9, .. }));
        assert!(SubmitError::ShuttingDown.to_string().contains("shutting down"));
    }
}
