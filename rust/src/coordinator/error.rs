//! The unified coordinator error: one enum for every way a submission
//! can fail, from local admission (`Rejected`, `Overloaded`) through the
//! wire edge's quota/protocol failures (`RateLimited`, `Parse`, ...) to
//! cluster routing (`Unavailable`). Every variant owns a **stable wire
//! code** ([`Error::wire_code`]), so the same value travels losslessly
//! router → worker → client: a worker's typed rejection re-encodes on
//! the router byte-identically to what the worker sent.
//!
//! Before PR 7 this surface was split: `SubmitError` (three variants,
//! in-process) and a separate `ErrorCode`/`WireError` pair in the RPC
//! protocol, glued by a free function `code_for_submit_error`. The split
//! meant the router would have had to translate between two error
//! vocabularies at every hop. Now there is one vocabulary; the protocol
//! layer only (de)serializes it.
//!
//! Compatibility: the numeric codes and the `Rejected`/`Overloaded`/
//! `ShuttingDown` Display strings are pinned by the golden fixtures in
//! `tests/fixtures/rpc/` — changing either is a wire break.

use thiserror::Error as ThisError;

use super::request::JobKind;
use crate::hybrid::registry::Tier;

/// Every way a submission can fail, with a stable wire code per variant.
///
/// Standard JSON-RPC codes cover transport/shape errors; the
/// `-32000..` implementation range carries the coordinator's typed
/// backpressure contract. `Unavailable` (−32006, new with cluster mode)
/// reports that routing exhausted every reachable replica.
#[derive(Clone, Debug, PartialEq, ThisError)]
pub enum Error {
    /// Frame payload was not valid JSON (wire code −32700).
    #[error("parse error: {0}")]
    Parse(String),
    /// JSON was valid but not a well-formed request object (−32600).
    #[error("invalid request: {0}")]
    InvalidRequest(String),
    /// Unknown `method` (−32601).
    #[error("method not found: {0}")]
    MethodNotFound(String),
    /// Params failed to decode into the method's types (−32602).
    #[error("invalid params: {0}")]
    InvalidParams(String),
    /// Server-side invariant failure (result channel died, ...) (−32603).
    #[error("internal error: {0}")]
    Internal(String),
    /// The payload failed shape/value admission for its lane (−32001).
    #[error("admission rejected: {0}")]
    Rejected(String),
    /// Every shard of the lane's bounded queue is at capacity (−32002).
    /// The typed fields are the backpressure signal's structured data on
    /// the wire; the message is derived from them, so decode rebuilds
    /// the variant losslessly from `data` alone.
    #[error("lane {kind:?}@{tier:?} overloaded: {queued} jobs queued at capacity {capacity}")]
    Overloaded {
        kind: JobKind,
        tier: Tier,
        queued: usize,
        capacity: usize,
    },
    /// The coordinator is draining; no new work is accepted (−32003).
    #[error("coordinator is shutting down")]
    ShuttingDown,
    /// Client exceeded its token-bucket submission rate (−32004).
    #[error("rate limited: {0}")]
    RateLimited(String),
    /// Client exceeded its in-flight job quota (−32005).
    #[error("too many jobs in flight: {0}")]
    TooManyInFlight(String),
    /// No backend/shard could take the job: the target worker (and every
    /// failover replica) was unreachable or the transport died mid-job
    /// (−32006).
    #[error("backend unavailable: {0}")]
    Unavailable(String),
    /// An authenticated result failed verification — MAC lane mismatch,
    /// exponent-duplicate mismatch, checksum/Freivalds rejection — and
    /// every resubmission attempt also failed to produce a verified
    /// result. The corrupted values are never delivered (−32007).
    #[error("integrity failure: {0}")]
    IntegrityFailure(String),
}

/// `(wire code, label)` of every variant, in table order. Property tests
/// iterate this; it is the single source of the code table.
pub const WIRE_CODES: [(i64, &str); 12] = [
    (-32700, "parse_error"),
    (-32600, "invalid_request"),
    (-32601, "method_not_found"),
    (-32602, "invalid_params"),
    (-32603, "internal"),
    (-32001, "rejected"),
    (-32002, "overloaded"),
    (-32003, "shutting_down"),
    (-32004, "rate_limited"),
    (-32005, "too_many_in_flight"),
    (-32006, "unavailable"),
    (-32007, "integrity_failure"),
];

impl Error {
    /// The stable wire code. Committed fixtures assert these values;
    /// changing one is a wire break.
    pub fn wire_code(&self) -> i64 {
        match self {
            Error::Parse(_) => -32700,
            Error::InvalidRequest(_) => -32600,
            Error::MethodNotFound(_) => -32601,
            Error::InvalidParams(_) => -32602,
            Error::Internal(_) => -32603,
            Error::Rejected(_) => -32001,
            Error::Overloaded { .. } => -32002,
            Error::ShuttingDown => -32003,
            Error::RateLimited(_) => -32004,
            Error::TooManyInFlight(_) => -32005,
            Error::Unavailable(_) => -32006,
            Error::IntegrityFailure(_) => -32007,
        }
    }

    /// Human label of the variant's code (metrics/log lines).
    pub fn code_label(&self) -> &'static str {
        WIRE_CODES
            .iter()
            .find(|(c, _)| *c == self.wire_code())
            .map(|(_, l)| *l)
            .expect("every variant has a table entry")
    }

    /// True for the backpressure codes a well-behaved client answers
    /// with backoff-and-retry (as opposed to fixing its request).
    /// `Unavailable` counts: the job was never executed and a replica
    /// may come back.
    pub fn is_backpressure(&self) -> bool {
        matches!(
            self,
            Error::Overloaded { .. }
                | Error::ShuttingDown
                | Error::RateLimited(_)
                | Error::TooManyInFlight(_)
                | Error::Unavailable(_)
        )
    }

    /// Rebuild a variant from its wire code and message — the inverse of
    /// encoding `self.to_string()` as the wire message. Each variant's
    /// Display prefix is stripped back off, so
    /// `Error::from_wire(e.wire_code(), &e.to_string())` round-trips the
    /// payload exactly. `Overloaded` is the exception: its fields travel
    /// as structured `data` (the message is derived), so this returns a
    /// zeroed placeholder the protocol layer overwrites from `data`.
    /// `None` for unknown codes.
    pub fn from_wire(code: i64, message: &str) -> Option<Error> {
        let strip = |prefix: &str| message.strip_prefix(prefix).unwrap_or(message).to_string();
        Some(match code {
            -32700 => Error::Parse(strip("parse error: ")),
            -32600 => Error::InvalidRequest(strip("invalid request: ")),
            -32601 => Error::MethodNotFound(strip("method not found: ")),
            -32602 => Error::InvalidParams(strip("invalid params: ")),
            -32603 => Error::Internal(strip("internal error: ")),
            -32001 => Error::Rejected(strip("admission rejected: ")),
            -32002 => Error::Overloaded {
                kind: JobKind::DotHybrid,
                tier: Tier::Paper,
                queued: 0,
                capacity: 0,
            },
            -32003 => Error::ShuttingDown,
            -32004 => Error::RateLimited(strip("rate limited: ")),
            -32005 => Error::TooManyInFlight(strip("too many jobs in flight: ")),
            -32006 => Error::Unavailable(strip("backend unavailable: ")),
            -32007 => Error::IntegrityFailure(strip("integrity failure: ")),
            _ => return None,
        })
    }
}

/// Pre-PR7 name of the submission-error surface, now the unified enum.
#[deprecated(note = "use coordinator::Error — submission and wire errors are one enum now")]
pub type SubmitError = Error;

#[cfg(test)]
mod tests {
    use super::*;

    fn one_of_each() -> Vec<Error> {
        vec![
            Error::Parse("frame is not UTF-8".into()),
            Error::InvalidRequest("missing method".into()),
            Error::MethodNotFound("unknown method \"warp\"".into()),
            Error::InvalidParams("spec without kind".into()),
            Error::Internal("result channel closed".into()),
            Error::Rejected("bad shape".into()),
            Error::Overloaded {
                kind: JobKind::DotHybrid,
                tier: Tier::Wide,
                queued: 32,
                capacity: 32,
            },
            Error::ShuttingDown,
            Error::RateLimited("submission rate above 100/s".into()),
            Error::TooManyInFlight("cap 256".into()),
            Error::Unavailable("no reachable worker for dot/hrfna@paper".into()),
            Error::IntegrityFailure("MAC mismatch in channel 3 after 2 resubmits".into()),
        ]
    }

    #[test]
    fn codes_are_stable_unique_and_total() {
        let errors = one_of_each();
        assert_eq!(errors.len(), WIRE_CODES.len());
        let mut codes: Vec<i64> = errors.iter().map(|e| e.wire_code()).collect();
        assert_eq!(codes, WIRE_CODES.iter().map(|(c, _)| *c).collect::<Vec<_>>());
        codes.sort();
        codes.dedup();
        assert_eq!(codes.len(), WIRE_CODES.len(), "codes must be unique");
        for e in &errors {
            assert_eq!(
                WIRE_CODES.iter().find(|(c, _)| *c == e.wire_code()).unwrap().1,
                e.code_label()
            );
        }
    }

    #[test]
    fn display_message_round_trips_through_from_wire() {
        for e in one_of_each() {
            let back = Error::from_wire(e.wire_code(), &e.to_string()).unwrap();
            match &e {
                // Overloaded rebuilds from structured data, not the
                // message; from_wire alone yields the placeholder.
                Error::Overloaded { .. } => {
                    assert_eq!(back.wire_code(), e.wire_code());
                }
                _ => assert_eq!(back, e, "lossless round trip for {e}"),
            }
        }
        assert_eq!(Error::from_wire(-1, "nope"), None);
    }

    #[test]
    fn backpressure_partition() {
        assert!(!Error::Rejected("x".into()).is_backpressure());
        assert!(!Error::Parse("x".into()).is_backpressure());
        assert!(!Error::Internal("x".into()).is_backpressure());
        assert!(Error::ShuttingDown.is_backpressure());
        assert!(Error::Unavailable("x".into()).is_backpressure());
        assert!(Error::RateLimited("x".into()).is_backpressure());
        // Integrity failures are NOT retry-with-backoff material: the
        // router already exhausted resubmission before surfacing one.
        assert!(!Error::IntegrityFailure("x".into()).is_backpressure());
    }

    #[test]
    fn legacy_display_strings_are_preserved() {
        // These exact strings are pinned by the golden wire fixtures.
        let e = Error::Overloaded {
            kind: JobKind::DotHybrid,
            tier: Tier::Paper,
            queued: 9,
            capacity: 8,
        };
        assert_eq!(
            e.to_string(),
            "lane DotHybrid@Paper overloaded: 9 jobs queued at capacity 8"
        );
        assert_eq!(
            Error::Rejected("bad".into()).to_string(),
            "admission rejected: bad"
        );
        assert_eq!(Error::ShuttingDown.to_string(), "coordinator is shutting down");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_alias_still_names_the_enum() {
        let e: SubmitError = Error::ShuttingDown;
        assert_eq!(e.wire_code(), -32003);
    }
}
