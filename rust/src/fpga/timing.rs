//! Achievable-Fmax model per pipeline class (UltraScale+ speedgrade-2).
//!
//! Calibration anchors (post-route, realistic rather than datasheet-best):
//! * Short (≤ 20-bit) carry chains + one LUT level retime to ≈ 2.0–2.2 ns
//!   → ≈ 480 MHz. This is the residue channel pipeline: the paper's
//!   "carry-free, short carry chains" argument (§VI-B).
//! * Vendor FP32 FMA pipelines on UltraScale+ close around 280–320 MHz in
//!   realistic congested designs (alignment/normalization shifter stages
//!   dominate) → 285 MHz.
//! * BFP integer MAC with alignment shifter: ≈ 380 MHz.
//! * Plain fixed-point DSP MACC: ≈ 520 MHz (DSP48E2 f_max bound).
//!
//! The model exposes *ratios* through one consistent table; per-design
//! scaling (congestion, fanout of the modulus constants, k) applies small
//! derates so parameter sweeps behave plausibly.

use super::resources::FormatArch;
use crate::config::HrfnaConfig;

/// Fmax in MHz for one MAC pipeline of `format` under config `cfg`.
pub fn fmax_mhz(format: FormatArch, cfg: &HrfnaConfig) -> f64 {
    match format {
        FormatArch::Hrfna => {
            // Base 480 MHz for 16-bit channels; wider channels stretch the
            // Barrett correction carry chain; many channels add routing
            // pressure (≈1%/channel past 8).
            let w = cfg
                .moduli
                .iter()
                .map(|&m| (m as f64).log2().ceil())
                .fold(0.0, f64::max);
            let width_derate = 1.0 + 0.02 * (w - 16.0).max(0.0);
            let k_derate = 1.0 + 0.01 * (cfg.moduli.len() as f64 - 8.0).max(0.0);
            470.0 / (width_derate * k_derate)
        }
        FormatArch::Fp32 => 260.0,
        FormatArch::Bfp => 380.0,
        FormatArch::Fixed => 520.0,
    }
}

/// Pipeline depth (cycles of latency) for one MAC of the format. Loop-
/// carried accumulation cares about the *adder* segment only.
pub fn mac_latency_cycles(format: FormatArch) -> u32 {
    match format {
        FormatArch::Hrfna => 6, // modmul 4 + modadd 1 + channel skew reg 1
        FormatArch::Fp32 => 11, // mul 3 + align/add/normalize/round 8
        FormatArch::Bfp => 5,
        FormatArch::Fixed => 3,
    }
}

/// Latency of the *accumulation* (add) segment alone — the loop-carried
/// dependency bound for single-accumulator reduction loops (§VII-B: FP32
/// dot products stall on this; HRFNA's 1-cycle modadd does not).
pub fn accumulate_latency_cycles(format: FormatArch) -> u32 {
    match format {
        FormatArch::Hrfna => 1, // carry-free modadd closes in one cycle
        FormatArch::Fp32 => 8,  // align + add + normalize + round
        FormatArch::Bfp => 2,   // int add + conditional renorm flag
        FormatArch::Fixed => 1,
    }
}

/// CRT normalization engine latency (cycles): reconstruction adder tree +
/// shift + re-encode (§VI-E). Invoked rarely; off the critical path.
pub fn normalization_latency_cycles(cfg: &HrfnaConfig) -> u32 {
    // log2(k) tree levels × 2 + constant-mult 4 + shift 2 + re-encode 4.
    let k = cfg.moduli.len() as f64;
    (2.0 * k.log2().ceil() + 10.0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HrfnaConfig {
        HrfnaConfig::paper_default()
    }

    #[test]
    fn hrfna_clocks_faster_than_fp32() {
        let c = cfg();
        assert!(fmax_mhz(FormatArch::Hrfna, &c) > 1.5 * fmax_mhz(FormatArch::Fp32, &c));
    }

    #[test]
    fn achieves_table2_target() {
        // Table II target clock is 300 MHz: HRFNA must close it.
        assert!(fmax_mhz(FormatArch::Hrfna, &cfg()) >= 300.0);
    }

    #[test]
    fn wider_moduli_derate_fmax() {
        let base = cfg();
        let mut wide = cfg();
        wide.moduli = crate::rns::moduli::generate_prime_moduli(8, 24);
        wide.tau_bits = 160;
        assert!(fmax_mhz(FormatArch::Hrfna, &wide) < fmax_mhz(FormatArch::Hrfna, &base));
    }

    #[test]
    fn accumulate_latency_is_the_fp32_weakness() {
        assert_eq!(accumulate_latency_cycles(FormatArch::Hrfna), 1);
        assert!(accumulate_latency_cycles(FormatArch::Fp32) >= 6);
    }

    #[test]
    fn norm_latency_reasonable() {
        let l = normalization_latency_cycles(&cfg());
        assert!(l >= 10 && l <= 40, "latency={l}");
    }
}
