//! Power and energy model (paper §VII: "up to 1.9× energy efficiency
//! improvement", Table III "Energy Efficiency ≈ 0.52× [FP32 energy]").
//!
//! Dynamic power per resource class at reference toggle activity and
//! 100 MHz, scaled linearly with clock (UltraScale+ XPE-class coefficients):
//!   * LUT:  ≈ 4.5 µW   * FF: ≈ 1.5 µW   * DSP48E2: ≈ 250 µW
//!   * BRAM36: ≈ 110 µW per active block
//! Activity factors: the FP32 normalization/alignment shifters toggle on
//! every operand (α ≈ 0.25); residue datapaths carry near-white data
//! (α ≈ 0.18) but skip per-op normalization entirely; the CRT engine is
//! active only during normalization events (duty factored in).
//!
//! Energy per MAC = P_dyn / throughput — so the efficiency ratio emerges
//! from the resource ratio × activity ratio × throughput ratio rather than
//! being hard-coded.

use super::pipeline::WorkloadTiming;
use super::resources::{FormatArch, Resources};

/// µW per unit resource at 100 MHz, α = 1.
const UW_PER_LUT: f64 = 4.5;
const UW_PER_FF: f64 = 1.5;
const UW_PER_DSP: f64 = 150.0;
const UW_PER_BRAM: f64 = 110.0;

/// Format-dependent switching activity of the datapath.
pub fn activity(format: FormatArch) -> f64 {
    match format {
        // Residue channels: data toggling only — no shifter churn, no
        // per-op normalization (the §VIII-A energy argument).
        FormatArch::Hrfna => 0.15,
        // Alignment + normalization barrel shifters and round logic
        // toggle across their full width on every operand.
        FormatArch::Fp32 => 0.30,
        FormatArch::Bfp => 0.28,
        FormatArch::Fixed => 0.15,
    }
}

/// BFP energy multiplier for block formation: building shared-exponent
/// blocks requires a max-exponent scan pass and a second read of every
/// operand — energy the MAC-level resource model does not see.
const BFP_BLOCK_FORMATION_FACTOR: f64 = 1.9;

/// Dynamic power (mW) of `res` at `fmax_mhz` with format activity.
pub fn dynamic_power_mw(res: &Resources, format: FormatArch, fmax_mhz: f64) -> f64 {
    let uw_at_100 = res.lut * UW_PER_LUT
        + res.ff * UW_PER_FF
        + res.dsp * UW_PER_DSP
        + res.bram * UW_PER_BRAM;
    uw_at_100 * activity(format) * (fmax_mhz / 100.0) / 1000.0
}

/// Energy per MAC-equivalent operation, nanojoules.
pub fn energy_per_mac_nj(
    res: &Resources,
    format: FormatArch,
    timing: &WorkloadTiming,
) -> f64 {
    let p_mw = dynamic_power_mw(res, format, timing.fmax_mhz);
    // mW / Mops = nJ per op.
    let base = p_mw / timing.throughput_mops;
    if matches!(format, FormatArch::Bfp) {
        base * BFP_BLOCK_FORMATION_FACTOR
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HrfnaConfig;
    use crate::fpga::pipeline::{model_workload, WorkloadKind};
    use crate::fpga::resources::mac_unit;

    #[test]
    fn power_scales_with_clock_and_resources() {
        let r = Resources { lut: 100.0, ff: 100.0, dsp: 1.0, bram: 0.0 };
        let p1 = dynamic_power_mw(&r, FormatArch::Fixed, 100.0);
        let p2 = dynamic_power_mw(&r, FormatArch::Fixed, 200.0);
        assert!((p2 / p1 - 2.0).abs() < 1e-9);
        let p3 = dynamic_power_mw(&r.times(2.0), FormatArch::Fixed, 100.0);
        assert!((p3 / p1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn hrfna_energy_ratio_in_paper_band() {
        // Table III "All Workloads": HRFNA ≈ 0.52× FP32 energy/op
        // (≈ 1.9× efficiency). Accept 0.4–0.7.
        let cfg = HrfnaConfig::paper_default();
        let kind = WorkloadKind::Dot { n: 65536 };
        let h_res = mac_unit(FormatArch::Hrfna, &cfg, 16);
        let f_res = mac_unit(FormatArch::Fp32, &cfg, 16);
        let h_t = model_workload(FormatArch::Hrfna, kind, &cfg, 16);
        let f_t = model_workload(FormatArch::Fp32, kind, &cfg, 0);
        let eh = energy_per_mac_nj(&h_res, FormatArch::Hrfna, &h_t);
        let ef = energy_per_mac_nj(&f_res, FormatArch::Fp32, &f_t);
        let ratio = eh / ef;
        assert!((0.35..=0.75).contains(&ratio), "energy ratio={ratio}");
    }

    #[test]
    fn bfp_energy_between_hrfna_and_fp32() {
        // Table III: BFP ≈ 0.7× FP32.
        let cfg = HrfnaConfig::paper_default();
        let kind = WorkloadKind::Dot { n: 65536 };
        let b_res = mac_unit(FormatArch::Bfp, &cfg, 16);
        let f_res = mac_unit(FormatArch::Fp32, &cfg, 16);
        let b_t = model_workload(FormatArch::Bfp, kind, &cfg, 0);
        let f_t = model_workload(FormatArch::Fp32, kind, &cfg, 0);
        let eb = energy_per_mac_nj(&b_res, FormatArch::Bfp, &b_t);
        let ef = energy_per_mac_nj(&f_res, FormatArch::Fp32, &f_t);
        let ratio = eb / ef;
        assert!((0.2..=0.95).contains(&ratio), "bfp ratio={ratio}");
    }
}
