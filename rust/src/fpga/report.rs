//! Table II-style implementation report and the iso-throughput resource
//! comparison behind the "38–55% LUT reduction" headline (§I, §VII).

use super::pipeline::{model_workload, WorkloadKind, WorkloadTiming};
use super::resources::{mac_unit, FormatArch, Resources};
use super::timing;
use crate::config::HrfnaConfig;
use crate::util::table::{eng, Table};

/// Render the paper's Table II (RTL configuration and setup) for `cfg`.
pub fn table2(cfg: &HrfnaConfig) -> Table {
    let mut t = Table::new(
        "Table II — RTL Configuration and FPGA Implementation Setup",
        &["Parameter", "Value", "Notes"],
    )
    .aligns(&[
        crate::util::table::Align::Left,
        crate::util::table::Align::Left,
        crate::util::table::Align::Left,
    ]);
    let moduli = cfg
        .moduli
        .iter()
        .map(|m| m.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    t.rowv(&[
        "Modulus set {m_i}".to_string(),
        moduli,
        "pairwise coprime".to_string(),
    ]);
    t.rowv(&[
        "Composite modulus M".to_string(),
        format!("~2^{:.1}", cfg.m_bits()),
        "residue-domain integer range".to_string(),
    ]);
    t.rowv(&[
        "Channels k".to_string(),
        cfg.k().to_string(),
        "parallel residue lanes".to_string(),
    ]);
    t.rowv(&[
        "Exponent width w_f".to_string(),
        cfg.exponent_width.to_string(),
        "scaling range".to_string(),
    ]);
    t.rowv(&[
        "Threshold tau".to_string(),
        format!("2^{}", cfg.tau_bits),
        "normalization trigger".to_string(),
    ]);
    t.rowv(&[
        "Scaling step s".to_string(),
        cfg.scale_step.to_string(),
        "hardware shifter granularity".to_string(),
    ]);
    t.rowv(&[
        "FPGA target".to_string(),
        "ZCU104 (ZU7EV) [modeled]".to_string(),
        "analytical model, see DESIGN.md".to_string(),
    ]);
    t.rowv(&[
        "Clock target".to_string(),
        format!("{:.0} MHz", cfg.clock_mhz),
        format!(
            "achieved Fmax (model): {:.0} MHz",
            timing::fmax_mhz(FormatArch::Hrfna, cfg)
        ),
    ]);
    t
}

/// One row of the iso-throughput resource comparison.
#[derive(Clone, Debug)]
pub struct IsoThroughputRow {
    pub format: FormatArch,
    pub units_needed: f64,
    pub resources: Resources,
    pub timing: WorkloadTiming,
}

/// Resource comparison at *matched workload throughput*: how much fabric
/// does each format spend to sustain the throughput HRFNA reaches with one
/// MAC unit on `kind`? (The paper's LUT-reduction headline is this
/// comparison: slower formats must replicate units to keep up.)
pub fn iso_throughput_comparison(
    cfg: &HrfnaConfig,
    kind: WorkloadKind,
    norm_events: u64,
) -> Vec<IsoThroughputRow> {
    let formats = [
        FormatArch::Hrfna,
        FormatArch::Fp32,
        FormatArch::Bfp,
        FormatArch::Fixed,
    ];
    let h_t = model_workload(FormatArch::Hrfna, kind, cfg, norm_events);
    formats
        .iter()
        .map(|&fmt| {
            let t = model_workload(fmt, kind, cfg, if fmt == FormatArch::Hrfna { norm_events } else { 0 });
            let units = (h_t.throughput_mops / t.throughput_mops).max(1.0);
            IsoThroughputRow {
                format: fmt,
                units_needed: units,
                resources: mac_unit(fmt, cfg, 16).times(units),
                timing: t,
            }
        })
        .collect()
}

/// LUT reduction of HRFNA vs FP32 at iso-throughput (the 38–55% claim).
pub fn lut_reduction_vs_fp32(cfg: &HrfnaConfig, kind: WorkloadKind, norm_events: u64) -> f64 {
    let rows = iso_throughput_comparison(cfg, kind, norm_events);
    let h = rows.iter().find(|r| r.format == FormatArch::Hrfna).unwrap();
    let f = rows.iter().find(|r| r.format == FormatArch::Fp32).unwrap();
    1.0 - h.resources.lut / f.resources.lut
}

/// Render the iso-throughput comparison as a table.
pub fn resource_table(cfg: &HrfnaConfig, kind: WorkloadKind, norm_events: u64) -> Table {
    let mut t = Table::new(
        &format!(
            "Iso-throughput resources for {} (matched to HRFNA)",
            kind.label()
        ),
        &["Format", "Units", "LUT", "FF", "DSP", "BRAM", "Fmax MHz", "II"],
    );
    for row in iso_throughput_comparison(cfg, kind, norm_events) {
        t.rowv(&[
            row.format.name().to_string(),
            format!("{:.2}", row.units_needed),
            eng(row.resources.lut),
            eng(row.resources.ff),
            eng(row.resources.dsp),
            eng(row.resources.bram),
            format!("{:.0}", row.timing.fmax_mhz),
            format!("{:.2}", row.timing.effective_ii),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HrfnaConfig {
        HrfnaConfig::paper_default()
    }

    #[test]
    fn table2_has_all_parameters() {
        let t = table2(&cfg());
        let s = t.render();
        for needle in ["Modulus set", "tau", "Scaling step", "ZCU104", "Fmax"] {
            assert!(s.contains(needle), "missing {needle}");
        }
    }

    #[test]
    fn lut_reduction_in_paper_band_dot() {
        // Paper: 38–55% LUT reduction vs FP32.
        let r = lut_reduction_vs_fp32(&cfg(), WorkloadKind::Dot { n: 65536 }, 16);
        assert!((0.30..=0.60).contains(&r), "lut reduction={r}");
    }

    #[test]
    fn iso_comparison_has_four_formats() {
        let rows = iso_throughput_comparison(&cfg(), WorkloadKind::Dot { n: 4096 }, 1);
        assert_eq!(rows.len(), 4);
        let h = &rows[0];
        assert_eq!(h.format, FormatArch::Hrfna);
        assert!((h.units_needed - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fp32_needs_more_units_at_iso_throughput() {
        let rows = iso_throughput_comparison(&cfg(), WorkloadKind::Dot { n: 65536 }, 16);
        let f = rows.iter().find(|r| r.format == FormatArch::Fp32).unwrap();
        assert!(f.units_needed > 2.0, "units={}", f.units_needed);
    }
}
