//! Cycle-level workload timing model (paper §V, §VII performance results).
//!
//! Models, per format and workload:
//! * steady-state initiation interval — HRFNA's residue pipes accept one
//!   MAC/cycle (the Π = 1 claim); FP32 single-accumulator reductions stall
//!   on the loop-carried FP-add latency, mitigated (not eliminated) by
//!   partial-sum interleaving; BFP pays periodic block renormalization.
//! * normalization-engine occupancy: HRFNA normalization events run off
//!   the datapath; a stall is charged only if a *dependent* event arrives
//!   while the engine is busy (rare by §VII-E measurement).

use super::resources::FormatArch;
use super::timing;
use crate::config::HrfnaConfig;
use crate::workloads::rk4::RK4_MACS_PER_STEP;

/// Workload classes of the paper's evaluation (§VII).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Dot product of length n.
    Dot { n: u64 },
    /// Dense matmul m×k×n.
    Matmul { m: u64, k: u64, n: u64 },
    /// RK4: steps × [`RK4_MACS_PER_STEP`] (a 2-D nonlinear field).
    Rk4 { steps: u64 },
}

impl WorkloadKind {
    /// MAC-equivalent operation count.
    pub fn macs(&self) -> u64 {
        match *self {
            WorkloadKind::Dot { n } => n,
            WorkloadKind::Matmul { m, k, n } => m * k * n,
            WorkloadKind::Rk4 { steps } => steps * RK4_MACS_PER_STEP,
        }
    }

    /// Label for tables.
    pub fn label(&self) -> String {
        match *self {
            WorkloadKind::Dot { n } => format!("dot[{n}]"),
            WorkloadKind::Matmul { m, k, n } => format!("matmul[{m}x{k}x{n}]"),
            WorkloadKind::Rk4 { steps } => format!("rk4[{steps}]"),
        }
    }
}

/// Number of interleaved partial sums a reduction loop uses to hide the
/// accumulator latency. Vendor FP32 dot-product IPs interleave several
/// partial sums; full latency-deep interleaving costs a final reduction
/// pass and registers, so designs stop short of hiding all 8 cycles.
pub const FP32_PARTIAL_SUMS: u32 = 6;

/// Effective initiation interval (cycles per MAC) for a reduction-style
/// loop in the given format.
pub fn effective_ii(format: FormatArch, kind: WorkloadKind) -> f64 {
    let acc_lat = timing::accumulate_latency_cycles(format) as f64;
    match format {
        FormatArch::Hrfna | FormatArch::Fixed => 1.0, // 1-cycle accumulate
        FormatArch::Fp32 => {
            let hidden = FP32_PARTIAL_SUMS as f64;
            // Loop-carried dependency: II = ceil(acc_lat / partial_sums);
            // matmul tiles expose more independent accumulators, so the
            // dependency is better hidden there.
            match kind {
                // Single reduction stream: II = acc_lat / interleave depth.
                WorkloadKind::Dot { .. } => (acc_lat / hidden).max(1.0),
                // Independent output elements interleave across the tile,
                // fully hiding the adder latency.
                WorkloadKind::Matmul { .. } => 1.0,
                // Field evaluation is accumulate-chained like dot.
                WorkloadKind::Rk4 { .. } => (acc_lat / hidden).max(1.0),
            }
        }
        FormatArch::Bfp => {
            // 1/cycle + a 4-cycle block renormalization every 64 elements.
            1.0 + 4.0 / 64.0
        }
    }
}

/// Timing result for a workload in one format.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadTiming {
    pub format: FormatArch,
    pub fmax_mhz: f64,
    pub effective_ii: f64,
    pub cycles: f64,
    /// Cycles lost to normalization-engine conflicts (HRFNA only).
    pub norm_stall_cycles: f64,
    pub wall_time_us: f64,
    /// MAC-equivalents per second.
    pub throughput_mops: f64,
}

/// Model the execution of `kind` in `format`.
///
/// `norm_events` is the *measured* normalization count from the software
/// model (the bit-accurate run), so the timing model consumes real event
/// rates rather than assumptions — the §VII-E coupling.
pub fn model_workload(
    format: FormatArch,
    kind: WorkloadKind,
    cfg: &HrfnaConfig,
    norm_events: u64,
) -> WorkloadTiming {
    let fmax = timing::fmax_mhz(format, cfg);
    let ii = effective_ii(format, kind);
    let macs = kind.macs() as f64;
    let fill = timing::mac_latency_cycles(format) as f64;

    // Normalization stalls: the engine runs off the datapath; a stall is
    // charged only when a dependent value needs the engine while it is
    // busy. With events spaced thousands of ops apart (§VII-E) the chance
    // of overlap is the engine duty cycle itself — second-order. We charge
    // the conservative dependent-stall fraction below.
    let norm_lat = timing::normalization_latency_cycles(cfg) as f64;
    let norm_stalls = if matches!(format, FormatArch::Hrfna) {
        let duty = (norm_events as f64 * norm_lat) / (macs * ii).max(1.0);
        // dependent-arrival probability ≈ duty; expected wait ≈ lat/2.
        norm_events as f64 * duty * (norm_lat / 2.0)
    } else {
        0.0
    };

    let cycles = macs * ii + fill + norm_stalls;
    let wall_us = cycles / fmax; // MHz → µs
    WorkloadTiming {
        format,
        fmax_mhz: fmax,
        effective_ii: ii,
        cycles,
        norm_stall_cycles: norm_stalls,
        wall_time_us: wall_us,
        throughput_mops: macs / wall_us,
    }
}

/// Throughput ratio of `a` over `b` for the same workload.
pub fn speedup(a: &WorkloadTiming, b: &WorkloadTiming) -> f64 {
    a.throughput_mops / b.throughput_mops
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HrfnaConfig {
        HrfnaConfig::paper_default()
    }

    #[test]
    fn hrfna_dot_ii_is_one() {
        assert_eq!(effective_ii(FormatArch::Hrfna, WorkloadKind::Dot { n: 1 }), 1.0);
    }

    #[test]
    fn dot_speedup_in_paper_band() {
        // Paper §VII-B.3: up to 2.4× over FP32.
        let c = cfg();
        let kind = WorkloadKind::Dot { n: 65536 };
        let h = model_workload(FormatArch::Hrfna, kind, &c, 12);
        let f = model_workload(FormatArch::Fp32, kind, &c, 0);
        let s = speedup(&h, &f);
        assert!((2.0..=2.6).contains(&s), "speedup={s}");
    }

    #[test]
    fn matmul_speedup_in_paper_band() {
        // Paper §VII-C.3: 1.8–2.2×.
        let c = cfg();
        let kind = WorkloadKind::Matmul { m: 128, k: 128, n: 128 };
        let h = model_workload(FormatArch::Hrfna, kind, &c, 300);
        let f = model_workload(FormatArch::Fp32, kind, &c, 0);
        let s = speedup(&h, &f);
        assert!((1.6..=2.3).contains(&s), "speedup={s}");
    }

    #[test]
    fn normalization_stalls_negligible_at_paper_rates() {
        // §VII-E: once per several thousand ops → Π stays ≈ 1.
        let c = cfg();
        let kind = WorkloadKind::Dot { n: 65536 };
        let events = 65536 / 4000;
        let t = model_workload(FormatArch::Hrfna, kind, &c, events);
        assert!(t.norm_stall_cycles / t.cycles < 1e-3);
    }

    #[test]
    fn heavy_normalization_degrades_gracefully() {
        let c = cfg();
        let kind = WorkloadKind::Dot { n: 4096 };
        let light = model_workload(FormatArch::Hrfna, kind, &c, 1);
        let heavy = model_workload(FormatArch::Hrfna, kind, &c, 2000);
        assert!(heavy.wall_time_us > light.wall_time_us);
    }

    #[test]
    fn macs_counts() {
        assert_eq!(WorkloadKind::Dot { n: 5 }.macs(), 5);
        assert_eq!(WorkloadKind::Matmul { m: 2, k: 3, n: 4 }.macs(), 24);
        assert_eq!(WorkloadKind::Rk4 { steps: 2 }.macs(), 80);
    }
}
