//! FPGA resource cost model (UltraScale+ ZU7EV class).
//!
//! Cost constants are calibrated to published operator footprints:
//!
//! * IEEE-754 FP32 adder (fabric, DSP-free, fully pipelined): ≈ 430 LUT /
//!   520 FF — dominated by the alignment and normalization barrel shifters
//!   plus round logic (Xilinx Floating-Point Operator–class figures).
//! * FP32 multiplier: ≈ 130 LUT / 190 FF / 3 DSP48E2 (24×24 via 27×18
//!   tiles).
//! * w-bit modular adder: add + conditional subtract + mux ≈ 2.5·w LUT,
//!   2·w FF — short carry chains, no DSP (paper §VI-B).
//! * w-bit modular multiplier (w ≤ 16): 1 DSP for the product, Barrett
//!   reduction with precomputed constants = 2 constant multipliers that
//!   map to 1 DSP + ≈ 3·w LUT of correction/conditional-subtract logic
//!   (paper §VI-B "precomputed constants and structured reduction").
//! * FP comparator (interval path): exponent+mantissa compare ≈ 60 LUT.
//!
//! The absolute constants matter less than their *ratios*: FP32's barrel
//! shifters and rounding are LUT-heavy, residue channels are DSP+wire —
//! that ratio is what produces the paper's 38–55% LUT reduction at
//! iso-throughput.

use crate::config::HrfnaConfig;

/// Resource vector: LUTs, flip-flops, DSP48 slices, BRAM36 blocks.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Resources {
    pub lut: f64,
    pub ff: f64,
    pub dsp: f64,
    pub bram: f64,
}

impl Resources {
    /// Component-wise sum.
    pub fn plus(&self, o: &Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            dsp: self.dsp + o.dsp,
            bram: self.bram + o.bram,
        }
    }

    /// Scale all components.
    pub fn times(&self, k: f64) -> Resources {
        Resources {
            lut: self.lut * k,
            ff: self.ff * k,
            dsp: self.dsp * k,
            bram: self.bram * k,
        }
    }

    /// "Equivalent LUT" scalarization for quick comparisons: a DSP48E2
    /// occupies silicon comparable to ≈ 60 LUT+FF pairs, a BRAM36 ≈ 180.
    pub fn lut_equivalent(&self) -> f64 {
        self.lut + 0.5 * self.ff + 60.0 * self.dsp + 180.0 * self.bram
    }
}

// ---------------------------------------------------------------------------
// Primitive unit costs
// ---------------------------------------------------------------------------

/// w-bit modular adder (add, conditional subtract, select).
pub fn modular_adder(w: u32) -> Resources {
    Resources {
        lut: 2.5 * w as f64,
        ff: 2.0 * w as f64,
        dsp: 0.0,
        bram: 0.0,
    }
}

/// w-bit modular multiplier with Barrett reduction (paper §VI-B). One
/// DSP48E2 computes the operand product; the Barrett *constant* multiplies
/// (by µ and m) fold into the DSP pre-adder/ALU cascade and a small LUT
/// correction network — constant-coefficient multiplier folding is standard
/// RNS-on-FPGA practice and is what §VI-B's "precomputed constants and
/// structured reduction logic chosen to minimize pipeline depth" describes.
pub fn modular_multiplier(w: u32) -> Resources {
    assert!(w <= 27, "single-DSP tile model only valid to 27 bits");
    Resources {
        lut: 2.0 * w as f64 + 12.0,
        ff: 3.0 * w as f64,
        dsp: 1.0,
        bram: 0.0,
    }
}

/// IEEE-754 FP32 adder, fabric implementation, fully pipelined.
pub fn fp32_adder() -> Resources {
    Resources {
        lut: 430.0,
        ff: 520.0,
        dsp: 0.0,
        bram: 0.0,
    }
}

/// IEEE-754 FP32 multiplier (DSP-based mantissa product).
pub fn fp32_multiplier() -> Resources {
    Resources {
        lut: 130.0,
        ff: 190.0,
        dsp: 3.0,
        bram: 0.0,
    }
}

/// BFP MAC lane: int mantissa multiply (1 DSP), alignment shifter, int
/// add, plus the block machinery a real BFP core carries — per-block
/// max-exponent scan, float↔block conversion and renormalization control
/// (≈180 LUT / 90 FF amortized per lane).
pub fn bfp_mac(mant_bits: u32) -> Resources {
    Resources {
        lut: 3.5 * mant_bits as f64 + 40.0 + 180.0,
        ff: 3.0 * mant_bits as f64 + 90.0,
        dsp: 1.0,
        bram: 0.0,
    }
}

/// FP32 reduction-loop overhead: the partial-sum interleave registers and
/// final-reduction control needed to keep a deep FP adder busy in
/// accumulation loops (see `pipeline::FP32_PARTIAL_SUMS`).
pub fn fp32_reduction_overhead() -> Resources {
    Resources {
        lut: 50.0,
        ff: 180.0,
        dsp: 0.0,
        bram: 0.0,
    }
}

/// Fixed-point Qm.n MAC (DSP MACC mode).
pub fn fixed_mac(total_bits: u32) -> Resources {
    Resources {
        lut: 1.0 * total_bits as f64 + 10.0,
        ff: 1.5 * total_bits as f64,
        dsp: 1.0,
        bram: 0.0,
    }
}

/// Floating-point comparator for the interval reduction tree (§III-E).
pub fn fp_comparator() -> Resources {
    Resources {
        lut: 60.0,
        ff: 40.0,
        dsp: 0.0,
        bram: 0.0,
    }
}

/// Exponent pipeline slice: ω_f-bit add/compare + bookkeeping (§VI-C).
pub fn exponent_pipe(omega_f: u32) -> Resources {
    Resources {
        lut: 1.5 * omega_f as f64 + 8.0,
        ff: 2.0 * omega_f as f64,
        dsp: 0.0,
        bram: 0.0,
    }
}

/// CRT normalization engine (§VI-E): per-channel constant multipliers
/// (r_i · T_i), a k-deep wide adder tree over ~log2(M)+w bits, the mod-M
/// correction, the power-of-two shifter and k re-encode reducers. Shared —
/// off the main datapath.
pub fn crt_engine(moduli: &[u64]) -> Resources {
    let k = moduli.len() as f64;
    let w: f64 = moduli
        .iter()
        .map(|&m| (m as f64).log2().ceil())
        .fold(0.0, f64::max);
    let m_bits: f64 = moduli.iter().map(|&m| (m as f64).log2()).sum();
    let wide = m_bits + w; // accumulator width of the CRT sum
    Resources {
        // k constant mults (2 DSP each via tiles), adder tree + mod-M
        // correction + shifter in fabric, k Barrett re-encoders.
        lut: k * (2.0 * w) + 3.0 * wide + 2.0 * wide + k * (3.0 * w + 12.0),
        ff: 2.0 * (k * w + wide),
        dsp: 2.0 * k + 2.0 * k, // reconstruction + re-encode constant mults
        bram: 1.0,              // CRT constant table
    }
}

// ---------------------------------------------------------------------------
// Per-format MAC-unit architectures
// ---------------------------------------------------------------------------

/// Formats the architecture model can cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FormatArch {
    Hrfna,
    Fp32,
    Bfp,
    Fixed,
}

impl FormatArch {
    /// Table label.
    pub fn name(&self) -> &'static str {
        match self {
            FormatArch::Hrfna => "HRFNA",
            FormatArch::Fp32 => "FP32",
            FormatArch::Bfp => "BFP",
            FormatArch::Fixed => "Fixed",
        }
    }
}

/// Resources for one fully pipelined MAC unit of the given format.
///
/// For HRFNA this is the paper's Fig. 2 arrangement: k parallel channel
/// MACs (modmul + modadd), the exponent pipe, and a 1/`share` amortized
/// slice of the interval-evaluation path and CRT normalization engine
/// (the engine is shared by `share` MAC units since normalization is rare,
/// §VII-E).
pub fn mac_unit(format: FormatArch, cfg: &HrfnaConfig, share: u32) -> Resources {
    match format {
        FormatArch::Hrfna => {
            let mut total = Resources::default();
            for &m in &cfg.moduli {
                let w = (m as f64).log2().ceil() as u32;
                total = total
                    .plus(&modular_multiplier(w))
                    .plus(&modular_adder(w));
            }
            total = total.plus(&exponent_pipe(cfg.exponent_width));
            // Interval path: one comparator + estimate logic per unit.
            total = total.plus(&fp_comparator());
            // Shared normalization engine, amortized.
            total.plus(&crt_engine(&cfg.moduli).times(1.0 / share.max(1) as f64))
        }
        FormatArch::Fp32 => fp32_adder()
            .plus(&fp32_multiplier())
            .plus(&fp32_reduction_overhead()),
        FormatArch::Bfp => bfp_mac(24),
        FormatArch::Fixed => fixed_mac(32),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HrfnaConfig {
        HrfnaConfig::paper_default()
    }

    #[test]
    fn primitive_costs_positive_and_ordered() {
        let ma = modular_adder(16);
        let mm = modular_multiplier(16);
        assert!(ma.lut > 0.0 && ma.dsp == 0.0);
        assert!(mm.dsp == 1.0);
        assert!(fp32_adder().lut > 5.0 * ma.lut, "FP32 add must dwarf modadd");
    }

    #[test]
    fn hrfna_mac_unit_composition() {
        let c = cfg();
        let r = mac_unit(FormatArch::Hrfna, &c, 16);
        // 8 channels × 1 DSP + amortized engine.
        assert!(r.dsp >= 8.0 && r.dsp < 13.0, "dsp={}", r.dsp);
        assert!(r.lut > 500.0 && r.lut < 2000.0, "lut={}", r.lut);
    }

    #[test]
    fn fp32_mac_is_lut_heavy() {
        let c = cfg();
        let h = mac_unit(FormatArch::Hrfna, &c, 16);
        let f = mac_unit(FormatArch::Fp32, &c, 16);
        // Per-unit: FP32 burns fewer DSPs but the HRFNA channel array uses
        // barely more LUT than a single FP32 adder's barrel shifters.
        assert!(f.lut > 500.0);
        assert!(h.lut / f.lut < 2.0);
    }

    #[test]
    fn engine_amortization_shrinks_with_share() {
        let c = cfg();
        let solo = mac_unit(FormatArch::Hrfna, &c, 1);
        let shared = mac_unit(FormatArch::Hrfna, &c, 32);
        assert!(shared.lut < solo.lut);
        assert!(shared.dsp < solo.dsp);
    }

    #[test]
    fn resources_algebra() {
        let a = Resources { lut: 1.0, ff: 2.0, dsp: 3.0, bram: 4.0 };
        let b = a.times(2.0).plus(&a);
        assert_eq!(b.lut, 3.0);
        assert_eq!(b.dsp, 9.0);
        assert!(a.lut_equivalent() > a.lut);
    }

    #[test]
    #[should_panic]
    fn wide_modmul_rejected() {
        modular_multiplier(30);
    }
}
