//! ZCU104-class FPGA microarchitecture model (paper §V–VI substitution —
//! see DESIGN.md: we have no Vivado/ZCU104, so the paper's post-P&R
//! measurements are reproduced with an analytical resource/timing/power
//! model over the *same microarchitecture decomposition*: parallel residue
//! channel pipelines, exponent pipe, interval control path, off-datapath
//! CRT normalization engine).
//!
//! * [`resources`] — LUT/FF/DSP/BRAM cost model per arithmetic unit,
//!   calibrated to published UltraScale+ operator costs (constants are
//!   documented at their definitions).
//! * [`timing`]    — achievable-Fmax model per pipeline class.
//! * [`pipeline`]  — cycle-level throughput model: initiation intervals,
//!   loop-carried accumulation dependencies, normalization-engine
//!   occupancy and stalls (Theorem-2-style Π→1 behaviour, §VII-E).
//! * [`power`]     — dynamic+static power and energy-per-operation.
//! * [`report`]    — Table II-style configuration/implementation report.

pub mod resources;
pub mod timing;
pub mod pipeline;
pub mod power;
pub mod report;

pub use pipeline::{WorkloadKind, WorkloadTiming};
pub use resources::{FormatArch, Resources};
