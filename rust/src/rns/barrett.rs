//! Barrett reduction with precomputed per-modulus constants, plus the
//! Shoup multiply and deferred-accumulator folds the planar lane kernels
//! are built on.
//!
//! This is the software mirror of the paper's RTL reduction logic (§VI-B:
//! "Reduction is implemented with precomputed constants and structured
//! reduction logic"). For a modulus `m < 2^32` we precompute
//! `mu = ⌊2^64 / m⌋`; writing `2^64 = mu·m + ρ` with `ρ ∈ [0, m)`, the
//! estimate `q = ⌊x·mu / 2^64⌋` for any `x < 2^64` satisfies
//! `x − q·m < x·ρ/2^64 + m < 2m`, so a **single** conditional subtraction
//! completes the reduction — branch-free, constant-time-ish, and exactly
//! the short carry chain the FPGA reduction unit evaluates. A second
//! (provably dead) conditional subtract is kept as a safety net.
//!
//! Lane kernels additionally rely on the 31-bit modulus invariant
//! ([`crate::rns::moduli::MAX_LANE_MODULUS_BITS`]): residue products then
//! fit in 62 bits, so they can be formed with one plain `u64` multiply and
//! summed raw into `u128` accumulators, deferring all reduction work to a
//! single [`Barrett::reduce_u128`] fold. [`barrett_set`] — the constructor
//! every modulus *set* goes through — enforces that invariant; the scalar
//! [`Barrett::new`] keeps the historical `m < 2^32` contract.

use crate::rns::moduli::MAX_LANE_MODULUS_BITS;
use thiserror::Error;

/// Why a modulus was rejected by the checked constructor.
#[derive(Clone, Copy, Debug, Error, PartialEq, Eq)]
pub enum BarrettError {
    /// Moduli below 2 have no residue arithmetic.
    #[error("modulus {0} is below 2")]
    TooSmall(u64),
    /// The deferred lane kernels need `m < 2^31` so raw products fit 62
    /// bits (see `rns::moduli::MAX_LANE_MODULUS_BITS`).
    #[error("modulus {0} exceeds 31 bits; lane kernels accumulate raw 62-bit products")]
    TooWide(u64),
}

/// Precomputed Barrett constants for one modulus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Barrett {
    /// The modulus (must be ≥ 2 and < 2^32).
    pub m: u64,
    /// ⌊2^64 / m⌋.
    mu: u64,
}

impl Barrett {
    /// Precompute constants for modulus `m` (scalar contract: `m < 2^32`).
    pub fn new(m: u64) -> Barrett {
        assert!(m >= 2, "modulus must be >= 2");
        assert!(m < 1 << 32, "Barrett path requires m < 2^32");
        // For m >= 2, floor(2^64 / m) <= 2^63 fits in u64.
        let mu = ((1u128 << 64) / m as u128) as u64;
        Barrett { m, mu }
    }

    /// Checked lane constructor: enforces the 31-bit modulus invariant the
    /// deferred-reduction kernels depend on (`2 ≤ m < 2^31`). Every
    /// modulus set goes through this via [`barrett_set`].
    pub fn try_new(m: u64) -> Result<Barrett, BarrettError> {
        if m < 2 {
            return Err(BarrettError::TooSmall(m));
        }
        if m >= 1 << MAX_LANE_MODULUS_BITS {
            return Err(BarrettError::TooWide(m));
        }
        Ok(Barrett::new(m))
    }

    /// True iff this modulus satisfies the 31-bit lane invariant, i.e. the
    /// deferred kernels may form raw `u64` products of its residues.
    #[inline]
    pub fn deferred_ok(&self) -> bool {
        self.m < 1 << MAX_LANE_MODULUS_BITS
    }

    /// The precomputed constant `⌊2^64 / m⌋`, for in-crate kernels (the
    /// AVX2 lane kernels emulate the 64×64 mul-hi of [`Barrett::reduce`]
    /// from 32-bit limb products and need the raw constant).
    #[inline]
    pub(crate) fn mu(&self) -> u64 {
        self.mu
    }

    /// `2^64 mod m`, derived from the stored constants:
    /// `2^64 = mu·m + ρ` so `ρ = 0 − mu·m` in wrapping u64 arithmetic.
    #[inline]
    fn pow2_64_mod(&self) -> u64 {
        self.mu.wrapping_mul(self.m).wrapping_neg()
    }

    /// Reduce `x` (any u64, in particular a product of two values < m)
    /// modulo `m`.
    #[inline]
    pub fn reduce(&self, x: u64) -> u64 {
        // q ≈ floor(x / m) via the high half of x * mu; the estimate is
        // off by less than 2 for every x < 2^64 (module doc), so the
        // remainder lands in [0, 2m) and one conditional subtract — kept
        // branch-free so the lane loops stay vectorizable — finishes.
        let q = ((x as u128 * self.mu as u128) >> 64) as u64;
        let mut r = x.wrapping_sub(q.wrapping_mul(self.m));
        r = if r >= self.m { r - self.m } else { r };
        // Dead by the error bound; retained as a safety net (still a cmov).
        r = if r >= self.m { r - self.m } else { r };
        r
    }

    /// Reduce a 128-bit value (a deferred lane accumulator) modulo `m`:
    /// split into `hi·2^64 + lo` and recombine through `2^64 mod m`. One
    /// call folds an entire accumulation chunk, which is the whole point
    /// of deferring.
    #[inline]
    pub fn reduce_u128(&self, x: u128) -> u64 {
        let lo = self.reduce(x as u64);
        let hi = self.reduce((x >> 64) as u64);
        // hi·2^64 + lo ≡ hi·ρ + lo (mod m); hi, ρ < m < 2^32 so the
        // product fits u64.
        self.add(self.reduce(hi * self.pow2_64_mod()), lo)
    }

    /// Shoup precomputation for a fixed multiplier: `⌊mult·2^64 / m⌋`.
    /// Pair with [`Barrett::mul_shoup`] when one multiplier streams
    /// against a whole lane (residue-domain scaling by `2^Δ mod m`).
    #[inline]
    pub fn shoup(&self, mult: u64) -> u64 {
        debug_assert!(mult < self.m);
        (((mult as u128) << 64) / self.m as u128) as u64
    }

    /// `(a * mult) mod m` with the precomputed Shoup constant: one mul-hi
    /// (`a·shoup`), one mul-lo pair (`a·mult − q·m`), and a single
    /// conditional subtract — the same error bound as [`Barrett::reduce`]
    /// gives `r < 2m` for any `a < 2^64`.
    #[inline]
    pub fn mul_shoup(&self, a: u64, mult: u64, shoup: u64) -> u64 {
        debug_assert!(a < self.m && mult < self.m);
        let q = ((a as u128 * shoup as u128) >> 64) as u64;
        let r = a.wrapping_mul(mult).wrapping_sub(q.wrapping_mul(self.m));
        if r >= self.m {
            r - self.m
        } else {
            r
        }
    }

    /// `(a * b) mod m` for `a, b < m`.
    #[inline]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.m && b < self.m);
        self.reduce(a * b)
    }

    /// `(a + b) mod m` for `a, b < m` (adder + conditional subtract, as in
    /// the RTL modular adder).
    #[inline]
    pub fn add(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.m && b < self.m);
        let s = a + b;
        if s >= self.m {
            s - self.m
        } else {
            s
        }
    }

    /// `(a - b) mod m` for `a, b < m`.
    #[inline]
    pub fn sub(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.m && b < self.m);
        if a >= b {
            a - b
        } else {
            a + self.m - b
        }
    }
}

/// A fixed multiplier paired with its precomputed Shoup constant
/// `⌊mult·2^64/m⌋` — the "one mul-hi + one mul-lo + one conditional
/// subtract" form a constant takes when it streams against many
/// residues (lane scaling, the normalization engine's re-encode basis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShoupMul {
    mult: u64,
    shoup: u64,
}

impl ShoupMul {
    /// Precompute the Shoup constant for `mult < m`.
    pub fn new(bar: &Barrett, mult: u64) -> ShoupMul {
        debug_assert!(mult < bar.m);
        ShoupMul {
            mult,
            shoup: bar.shoup(mult),
        }
    }

    /// The wrapped multiplier.
    #[inline]
    pub fn mult(&self) -> u64 {
        self.mult
    }

    /// `(a · mult) mod m` for `a < m`.
    #[inline]
    pub fn mul(&self, bar: &Barrett, a: u64) -> u64 {
        bar.mul_shoup(a, self.mult, self.shoup)
    }
}

/// Per-modulus table of `2^{-d} mod m` Shoup multipliers (odd `m` only —
/// 2 has no inverse modulo an even modulus). This is the normalization
/// engine's residue-domain re-encode constant set: Definition 4's
/// division by `2^s` becomes one channelwise Shoup multiply by
/// `2^{-s} mod m_i` instead of a BigUint re-encode
/// (`rns::crt::CrtContext::rescale_batch`).
#[derive(Clone, Debug)]
pub struct InvPow2 {
    /// `2^{-1} mod m` = `(m+1)/2` for odd `m`.
    inv2: u64,
    table: Vec<ShoupMul>,
}

impl Barrett {
    /// Build the inverse-power-of-two Shoup table `2^{-d} mod m` for
    /// `d < depth`. Returns `None` for even moduli (no inverse of 2).
    pub fn inv_pow2(&self, depth: usize) -> Option<InvPow2> {
        if self.m % 2 == 0 {
            return None;
        }
        let inv2 = (self.m + 1) / 2;
        let mut table = Vec::with_capacity(depth);
        let mut v = 1 % self.m;
        for _ in 0..depth {
            table.push(ShoupMul::new(self, v));
            v = self.mul(v, inv2);
        }
        Some(InvPow2 { inv2, table })
    }
}

impl InvPow2 {
    /// `(a · 2^{-s}) mod m` for `a < m`: one Shoup multiply on a table
    /// hit, a pow-ladder fallback beyond the table depth.
    #[inline]
    pub fn mul_inv_pow2(&self, bar: &Barrett, a: u64, s: u32) -> u64 {
        match self.table.get(s as usize) {
            Some(sm) => sm.mul(bar, a),
            None => bar.mul(a, crate::rns::moduli::pow_mod(self.inv2, s as u64, bar.m)),
        }
    }
}

/// Precompute Barrett contexts for a modulus set, validating the 31-bit
/// lane invariant (every set built here may take the deferred kernels).
/// Panics with the offending modulus on violation — modulus sets are
/// setup-time configuration, not request-path data.
pub fn barrett_set(moduli: &[u64]) -> Vec<Barrett> {
    moduli
        .iter()
        .map(|&m| Barrett::try_new(m).unwrap_or_else(|e| panic!("barrett_set: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::DEFAULT_MODULI;
    use crate::util::proptest::check;

    #[test]
    fn reduce_matches_rem_for_products() {
        for &m in &DEFAULT_MODULI {
            let b = Barrett::new(m);
            for (x, y) in [(0u64, 0u64), (1, 1), (m - 1, m - 1), (12345, 54321)] {
                assert_eq!(b.mul(x % m, y % m), (x % m) * (y % m) % m);
            }
        }
    }

    #[test]
    fn reduce_arbitrary_u64() {
        let b = Barrett::new(65521);
        for x in [0u64, 1, 65520, 65521, 65522, u64::MAX, u64::MAX - 1] {
            assert_eq!(b.reduce(x), x % 65521, "x={x}");
        }
    }

    #[test]
    fn add_sub_wrap() {
        let b = Barrett::new(97);
        assert_eq!(b.add(96, 96), 95);
        assert_eq!(b.sub(0, 1), 96);
        assert_eq!(b.sub(50, 20), 30);
    }

    #[test]
    fn small_and_large_moduli() {
        for m in [2u64, 3, 7, 255, 65536, (1 << 31) - 1, (1 << 32) - 5] {
            let b = Barrett::new(m);
            for x in [0u64, m - 1, m, 2 * m + 3, u64::MAX / 3] {
                assert_eq!(b.reduce(x), x % m, "m={m} x={x}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn modulus_too_large_panics() {
        Barrett::new(1 << 32);
    }

    #[test]
    fn try_new_enforces_lane_width() {
        assert_eq!(Barrett::try_new(0), Err(BarrettError::TooSmall(0)));
        assert_eq!(Barrett::try_new(1), Err(BarrettError::TooSmall(1)));
        assert_eq!(
            Barrett::try_new(1 << 31),
            Err(BarrettError::TooWide(1 << 31))
        );
        assert_eq!(
            Barrett::try_new((1 << 32) - 5),
            Err(BarrettError::TooWide((1 << 32) - 5))
        );
        let ok = Barrett::try_new((1 << 31) - 1).unwrap();
        assert!(ok.deferred_ok());
        // The scalar constructor still admits 32-bit moduli, but they are
        // flagged as unusable by the deferred kernels.
        assert!(!Barrett::new((1 << 32) - 5).deferred_ok());
    }

    #[test]
    #[should_panic(expected = "barrett_set")]
    fn barrett_set_rejects_wide_modulus() {
        barrett_set(&[65521, (1 << 32) - 5]);
    }

    #[test]
    fn reduce_u128_matches_rem() {
        for &m in &[3u64, 97, 65521, (1 << 31) - 1, (1 << 32) - 5] {
            let b = Barrett::new(m);
            for x in [
                0u128,
                1,
                (m as u128) * (m as u128),
                u64::MAX as u128,
                u64::MAX as u128 + 1,
                u128::MAX,
                u128::MAX - 7,
                1u128 << 64,
                (1u128 << 64) - 1,
            ] {
                assert_eq!(b.reduce_u128(x), (x % m as u128) as u64, "m={m} x={x}");
            }
        }
    }

    #[test]
    fn mul_shoup_matches_mul() {
        for &m in &[3u64, 97, 65521, (1 << 31) - 1] {
            let b = Barrett::new(m);
            for mult in [0u64, 1, 2, m / 2, m - 1] {
                let sh = b.shoup(mult);
                for a in [0u64, 1, m / 3, m / 2, m - 2, m - 1] {
                    let a = a % m;
                    assert_eq!(
                        b.mul_shoup(a, mult, sh),
                        b.mul(a, mult),
                        "m={m} a={a} mult={mult}"
                    );
                }
            }
        }
    }

    #[test]
    fn operands_at_the_extremes() {
        // Near m-1 (largest residues), zero, and the m/2 sign boundary
        // (values ≥ m/2 encode negatives in the M-complement convention —
        // the reduction itself must be agnostic to it).
        for &m in &[3u64, 97, 65521, (1 << 31) - 1, (1 << 32) - 5] {
            let b = Barrett::new(m);
            let half = m / 2;
            for x in [0u64, 1, half.saturating_sub(1), half, half + 1, m - 2, m - 1] {
                let x = x % m;
                for y in [0u64, 1, half % m, (m - 1) % m] {
                    assert_eq!(
                        b.mul(x, y),
                        ((x as u128 * y as u128) % m as u128) as u64,
                        "mul m={m} x={x} y={y}"
                    );
                    assert_eq!(b.add(x, y), (x + y) % m, "add m={m} x={x} y={y}");
                    assert_eq!(
                        b.sub(x, y),
                        ((x as i128 - y as i128).rem_euclid(m as i128)) as u64,
                        "sub m={m} x={x} y={y}"
                    );
                }
            }
            // The largest pre-reduction product: (m-1)² must reduce to 1.
            assert_eq!(b.mul(m - 1, m - 1), 1 % m, "(m-1)^2 mod m, m={m}");
        }
    }

    #[test]
    fn reduce_products_straddling_the_sign_boundary() {
        // M-complement negation is r -> m - r; products of "negative"
        // residues must land exactly like their integer counterparts.
        let m = 65521u64;
        let b = Barrett::new(m);
        for v in [1u64, 2, 1000, m / 2, m / 2 + 1] {
            let neg = (m - v) % m; // encodes -v
            // (-v)·(-v) ≡ v² and (-v)+v ≡ 0.
            assert_eq!(b.mul(neg, neg), b.mul(v % m, v % m), "v={v}");
            assert_eq!(b.add(neg, v % m), 0, "v={v}");
        }
    }

    #[test]
    fn prop_reduce_equals_rem() {
        check("barrett-reduce", |rng| {
            let m = rng.below((1 << 32) - 2) + 2;
            let b = Barrett::new(m);
            let x = rng.next_u64();
            crate::prop_assert!(b.reduce(x) == x % m, "m={m} x={x}");
            Ok(())
        });
    }

    #[test]
    fn prop_reduce_u128_and_shoup_equal_rem() {
        check("barrett-reduce-u128-shoup", |rng| {
            let m = rng.below((1u64 << 31) - 2) + 2;
            let b = Barrett::try_new(m).expect("lane-width modulus");
            let x = ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128;
            crate::prop_assert!(
                b.reduce_u128(x) == (x % m as u128) as u64,
                "reduce_u128 m={m} x={x}"
            );
            let a = rng.below(m);
            let mult = rng.below(m);
            let sh = b.shoup(mult);
            crate::prop_assert!(
                b.mul_shoup(a, mult, sh) == b.mul(a, mult),
                "mul_shoup m={m} a={a} mult={mult}"
            );
            Ok(())
        });
    }

    #[test]
    fn shoup_mul_wrapper_matches_mul() {
        for &m in &[3u64, 97, 65521, (1 << 31) - 1] {
            let b = Barrett::new(m);
            for mult in [0u64, 1, m / 2, m - 1] {
                let sm = ShoupMul::new(&b, mult);
                assert_eq!(sm.mult(), mult);
                for a in [0u64, 1, m / 3, m - 1] {
                    assert_eq!(sm.mul(&b, a % m), b.mul(a % m, mult), "m={m}");
                }
            }
        }
    }

    #[test]
    fn inv_pow2_inverts_doubling() {
        for &m in &[3u64, 97, 65521, (1 << 31) - 1] {
            let b = Barrett::new(m);
            let inv = b.inv_pow2(16).expect("odd modulus");
            for s in [0u32, 1, 5, 15, 16, 40, 200] {
                // (a·2^s)·2^{-s} ≡ a for any a < m.
                for a in [0u64, 1, m / 2, m - 1] {
                    let scaled = b.mul(a, crate::rns::moduli::pow_mod(2, s as u64, m));
                    assert_eq!(
                        inv.mul_inv_pow2(&b, scaled, s),
                        a,
                        "m={m} a={a} s={s}"
                    );
                }
            }
        }
    }

    #[test]
    fn inv_pow2_rejects_even_moduli() {
        assert!(Barrett::new(65536).inv_pow2(4).is_none());
        assert!(Barrett::new(2).inv_pow2(4).is_none());
        assert!(Barrett::new(65521).inv_pow2(4).is_some());
    }

    #[test]
    fn prop_field_axioms_mod_p() {
        check("barrett-axioms", |rng| {
            let m = 65521u64; // prime
            let b = Barrett::new(m);
            let x = rng.below(m);
            let y = rng.below(m);
            let z = rng.below(m);
            // distributivity: x*(y+z) == x*y + x*z (mod m)
            let lhs = b.mul(x, b.add(y, z));
            let rhs = b.add(b.mul(x, y), b.mul(x, z));
            crate::prop_assert!(lhs == rhs, "distributivity x={x} y={y} z={z}");
            // additive inverse
            crate::prop_assert!(b.add(x, b.sub(0, x)) == 0, "inverse x={x}");
            Ok(())
        });
    }
}
