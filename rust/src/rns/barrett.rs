//! Barrett reduction with precomputed per-modulus constants.
//!
//! This is the software mirror of the paper's RTL reduction logic (§VI-B:
//! "Reduction is implemented with precomputed constants and structured
//! reduction logic"). For a modulus `m < 2^32` we precompute
//! `mu = ⌊2^64 / m⌋`; for `x < m^2 ≤ 2^64` the estimate `q = ⌊x·mu / 2^64⌋`
//! satisfies `q ≤ ⌊x/m⌋ ≤ q + 2`, so at most two conditional subtractions
//! complete the reduction — branch-predictable and constant-ish time, which
//! is also why it maps to short FPGA carry chains.

/// Precomputed Barrett constants for one modulus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Barrett {
    /// The modulus (must be ≥ 2 and < 2^32).
    pub m: u64,
    /// ⌊2^64 / m⌋.
    mu: u64,
}

impl Barrett {
    /// Precompute constants for modulus `m`.
    pub fn new(m: u64) -> Barrett {
        assert!(m >= 2, "modulus must be >= 2");
        assert!(m < 1 << 32, "Barrett path requires m < 2^32");
        // For m >= 2, floor(2^64 / m) <= 2^63 fits in u64.
        let mu = ((1u128 << 64) / m as u128) as u64;
        Barrett { m, mu }
    }

    /// Reduce `x` (any u64, in particular a product of two values < m)
    /// modulo `m`.
    #[inline]
    pub fn reduce(&self, x: u64) -> u64 {
        // q ≈ floor(x / m) via the high half of x * mu.
        let q = ((x as u128 * self.mu as u128) >> 64) as u64;
        let mut r = x.wrapping_sub(q.wrapping_mul(self.m));
        // At most two correction steps.
        while r >= self.m {
            r -= self.m;
        }
        r
    }

    /// `(a * b) mod m` for `a, b < m`.
    #[inline]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.m && b < self.m);
        self.reduce(a * b)
    }

    /// `(a + b) mod m` for `a, b < m` (adder + conditional subtract, as in
    /// the RTL modular adder).
    #[inline]
    pub fn add(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.m && b < self.m);
        let s = a + b;
        if s >= self.m {
            s - self.m
        } else {
            s
        }
    }

    /// `(a - b) mod m` for `a, b < m`.
    #[inline]
    pub fn sub(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.m && b < self.m);
        if a >= b {
            a - b
        } else {
            a + self.m - b
        }
    }
}

/// Precompute Barrett contexts for a modulus set.
pub fn barrett_set(moduli: &[u64]) -> Vec<Barrett> {
    moduli.iter().map(|&m| Barrett::new(m)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::DEFAULT_MODULI;
    use crate::util::proptest::check;

    #[test]
    fn reduce_matches_rem_for_products() {
        for &m in &DEFAULT_MODULI {
            let b = Barrett::new(m);
            for (x, y) in [(0u64, 0u64), (1, 1), (m - 1, m - 1), (12345, 54321)] {
                assert_eq!(b.mul(x % m, y % m), (x % m) * (y % m) % m);
            }
        }
    }

    #[test]
    fn reduce_arbitrary_u64() {
        let b = Barrett::new(65521);
        for x in [0u64, 1, 65520, 65521, 65522, u64::MAX, u64::MAX - 1] {
            assert_eq!(b.reduce(x), x % 65521, "x={x}");
        }
    }

    #[test]
    fn add_sub_wrap() {
        let b = Barrett::new(97);
        assert_eq!(b.add(96, 96), 95);
        assert_eq!(b.sub(0, 1), 96);
        assert_eq!(b.sub(50, 20), 30);
    }

    #[test]
    fn small_and_large_moduli() {
        for m in [2u64, 3, 7, 255, 65536, (1 << 31) - 1, (1 << 32) - 5] {
            let b = Barrett::new(m);
            for x in [0u64, m - 1, m, 2 * m + 3, u64::MAX / 3] {
                assert_eq!(b.reduce(x), x % m, "m={m} x={x}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn modulus_too_large_panics() {
        Barrett::new(1 << 32);
    }

    #[test]
    fn operands_at_the_extremes() {
        // Near m-1 (largest residues), zero, and the m/2 sign boundary
        // (values ≥ m/2 encode negatives in the M-complement convention —
        // the reduction itself must be agnostic to it).
        for &m in &[3u64, 97, 65521, (1 << 31) - 1, (1 << 32) - 5] {
            let b = Barrett::new(m);
            let half = m / 2;
            for x in [0u64, 1, half.saturating_sub(1), half, half + 1, m - 2, m - 1] {
                let x = x % m;
                for y in [0u64, 1, half % m, (m - 1) % m] {
                    assert_eq!(
                        b.mul(x, y),
                        ((x as u128 * y as u128) % m as u128) as u64,
                        "mul m={m} x={x} y={y}"
                    );
                    assert_eq!(b.add(x, y), (x + y) % m, "add m={m} x={x} y={y}");
                    assert_eq!(
                        b.sub(x, y),
                        ((x as i128 - y as i128).rem_euclid(m as i128)) as u64,
                        "sub m={m} x={x} y={y}"
                    );
                }
            }
            // The largest pre-reduction product: (m-1)² must reduce to 1.
            assert_eq!(b.mul(m - 1, m - 1), 1 % m, "(m-1)^2 mod m, m={m}");
        }
    }

    #[test]
    fn reduce_products_straddling_the_sign_boundary() {
        // M-complement negation is r -> m - r; products of "negative"
        // residues must land exactly like their integer counterparts.
        let m = 65521u64;
        let b = Barrett::new(m);
        for v in [1u64, 2, 1000, m / 2, m / 2 + 1] {
            let neg = (m - v) % m; // encodes -v
            // (-v)·(-v) ≡ v² and (-v)+v ≡ 0.
            assert_eq!(b.mul(neg, neg), b.mul(v % m, v % m), "v={v}");
            assert_eq!(b.add(neg, v % m), 0, "v={v}");
        }
    }

    #[test]
    fn prop_reduce_equals_rem() {
        check("barrett-reduce", |rng| {
            let m = rng.below((1 << 32) - 2) + 2;
            let b = Barrett::new(m);
            let x = rng.next_u64();
            crate::prop_assert!(b.reduce(x) == x % m, "m={m} x={x}");
            Ok(())
        });
    }

    #[test]
    fn prop_field_axioms_mod_p() {
        check("barrett-axioms", |rng| {
            let m = 65521u64; // prime
            let b = Barrett::new(m);
            let x = rng.below(m);
            let y = rng.below(m);
            let z = rng.below(m);
            // distributivity: x*(y+z) == x*y + x*z (mod m)
            let lhs = b.mul(x, b.add(y, z));
            let rhs = b.add(b.mul(x, y), b.mul(x, z));
            crate::prop_assert!(lhs == rhs, "distributivity x={x} y={y} z={z}");
            // additive inverse
            crate::prop_assert!(b.add(x, b.sub(0, x)) == 0, "inverse x={x}");
            Ok(())
        });
    }
}
