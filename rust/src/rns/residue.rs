//! Residue vectors: encoding integers into per-channel residues and the
//! channelwise carry-free operations of paper Definition 2 / §IV-A,B.

use super::barrett::Barrett;
use crate::bigint::BigUint;

/// A residue vector over a modulus set: `r[i] = N mod m[i]`.
///
/// The modulus set itself lives in the surrounding context (`CrtContext` or
/// `HrfnaContext`); `ResidueVec` is plain data, mirroring how the RTL routes
/// residue words between channel pipelines.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ResidueVec {
    pub r: Vec<u64>,
}

impl ResidueVec {
    /// All-zero residues (the value 0).
    pub fn zero(k: usize) -> ResidueVec {
        ResidueVec { r: vec![0; k] }
    }

    /// Encode a small unsigned integer.
    pub fn encode_u64(x: u64, moduli: &[u64]) -> ResidueVec {
        ResidueVec {
            r: moduli.iter().map(|&m| x % m).collect(),
        }
    }

    /// Encode a big unsigned integer (used after normalization re-encoding,
    /// paper Definition 4 step "re-encode").
    pub fn encode_big(n: &BigUint, moduli: &[u64]) -> ResidueVec {
        ResidueVec {
            r: moduli.iter().map(|&m| n.rem_u64(m)).collect(),
        }
    }

    /// Number of channels.
    pub fn k(&self) -> usize {
        self.r.len()
    }

    /// True iff all residues are zero. NOTE: this is a *sufficient* zero
    /// test only when the represented integer is < M (always true here).
    pub fn is_zero(&self) -> bool {
        self.r.iter().all(|&x| x == 0)
    }

    /// Channelwise modular multiplication (Definition 2): r_Z = r_X ⊙ r_Y.
    pub fn mul(&self, other: &ResidueVec, ctx: &[Barrett]) -> ResidueVec {
        debug_assert_eq!(self.k(), other.k());
        debug_assert_eq!(self.k(), ctx.len());
        ResidueVec {
            r: self
                .r
                .iter()
                .zip(&other.r)
                .zip(ctx)
                .map(|((&a, &b), bar)| bar.mul(a, b))
                .collect(),
        }
    }

    /// Channelwise modular addition (exponent-synchronized add, §IV-B).
    pub fn add(&self, other: &ResidueVec, ctx: &[Barrett]) -> ResidueVec {
        debug_assert_eq!(self.k(), other.k());
        ResidueVec {
            r: self
                .r
                .iter()
                .zip(&other.r)
                .zip(ctx)
                .map(|((&a, &b), bar)| bar.add(a, b))
                .collect(),
        }
    }

    /// Channelwise modular subtraction.
    pub fn sub(&self, other: &ResidueVec, ctx: &[Barrett]) -> ResidueVec {
        debug_assert_eq!(self.k(), other.k());
        ResidueVec {
            r: self
                .r
                .iter()
                .zip(&other.r)
                .zip(ctx)
                .map(|((&a, &b), bar)| bar.sub(a, b))
                .collect(),
        }
    }

    /// In-place fused multiply-accumulate: `self += x ⊙ y` per channel —
    /// the hot loop of the Hybrid Dot Product (Alg. 1 step 2b/2c).
    #[inline]
    pub fn mac_assign(&mut self, x: &ResidueVec, y: &ResidueVec, ctx: &[Barrett]) {
        for i in 0..self.r.len() {
            let p = ctx[i].mul(x.r[i], y.r[i]);
            self.r[i] = ctx[i].add(self.r[i], p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::barrett::barrett_set;
    use crate::rns::moduli::DEFAULT_MODULI;
    use crate::util::proptest::check;

    fn ctx() -> Vec<Barrett> {
        barrett_set(&DEFAULT_MODULI)
    }

    #[test]
    fn encode_small() {
        let r = ResidueVec::encode_u64(100, &DEFAULT_MODULI);
        assert!(r.r.iter().all(|&x| x == 100));
        let r = ResidueVec::encode_u64(65521 + 3, &DEFAULT_MODULI);
        assert_eq!(r.r[0], 3);
        assert_eq!(r.r[1], 65524 - 65519);
    }

    #[test]
    fn encode_big_matches_u64() {
        let n = 123_456_789_012_345u64;
        let a = ResidueVec::encode_u64(n, &DEFAULT_MODULI);
        let b = ResidueVec::encode_big(&BigUint::from_u64(n), &DEFAULT_MODULI);
        assert_eq!(a, b);
    }

    #[test]
    fn mul_add_homomorphic_small() {
        // For values whose product stays < min(m), residue ops match integer ops.
        let c = ctx();
        let a = ResidueVec::encode_u64(123, &DEFAULT_MODULI);
        let b = ResidueVec::encode_u64(45, &DEFAULT_MODULI);
        assert_eq!(
            a.mul(&b, &c),
            ResidueVec::encode_u64(123 * 45, &DEFAULT_MODULI)
        );
        assert_eq!(
            a.add(&b, &c),
            ResidueVec::encode_u64(168, &DEFAULT_MODULI)
        );
        assert_eq!(a.sub(&b, &c), ResidueVec::encode_u64(78, &DEFAULT_MODULI));
    }

    #[test]
    fn mac_matches_mul_add() {
        let c = ctx();
        let mut acc = ResidueVec::encode_u64(7, &DEFAULT_MODULI);
        let x = ResidueVec::encode_u64(1234, &DEFAULT_MODULI);
        let y = ResidueVec::encode_u64(4321, &DEFAULT_MODULI);
        let want = acc.add(&x.mul(&y, &c), &c);
        acc.mac_assign(&x, &y, &c);
        assert_eq!(acc, want);
    }

    #[test]
    fn prop_residue_ops_match_u128_integers() {
        let c = ctx();
        check("residue-homomorphism", |rng| {
            let a = rng.next_u64() >> 16; // keep products in u128 range
            let b = rng.next_u64() >> 16;
            let ra = ResidueVec::encode_u64(a, &DEFAULT_MODULI);
            let rb = ResidueVec::encode_u64(b, &DEFAULT_MODULI);
            let prod = (a as u128) * (b as u128);
            let want_mul = ResidueVec::encode_big(
                &BigUint::from_u128(prod),
                &DEFAULT_MODULI,
            );
            crate::prop_assert!(ra.mul(&rb, &c) == want_mul, "mul a={a} b={b}");
            let want_add = ResidueVec::encode_big(
                &BigUint::from_u128(a as u128 + b as u128),
                &DEFAULT_MODULI,
            );
            crate::prop_assert!(ra.add(&rb, &c) == want_add, "add a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    fn zero_behaviour() {
        let c = ctx();
        let z = ResidueVec::zero(8);
        assert!(z.is_zero());
        let a = ResidueVec::encode_u64(99, &DEFAULT_MODULI);
        assert_eq!(a.mul(&z, &c), z);
        assert_eq!(a.add(&z, &c), a);
    }
}
