//! Residue Number System substrate (paper §II-D, §III-A).
//!
//! Residues are `u64` values below `u32`-sized moduli; channelwise modular
//! arithmetic uses Barrett reduction with precomputed constants — the same
//! "precomputed constants and structured reduction logic" the paper's RTL
//! uses (§VI-B) — and reconstruction goes through a precomputed CRT context
//! (or mixed-radix conversion for comparison-only paths).

// Lint tightening for the kernel layer: the lane loops are the crate's
// hottest code and must stay in iterator/zip form (vectorizable, no
// bounds checks) rather than index-loop form.
#![deny(clippy::needless_range_loop, clippy::manual_memcpy)]

pub mod moduli;
pub mod barrett;
pub mod residue;
pub mod crt;
pub mod plane;
// AVX2 implementations of the plane lane kernels; reached only through
// the runtime-dispatch shims in `plane` (never called directly), so the
// module stays crate-private. Compiled out entirely off x86_64.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub(crate) mod simd;

pub use barrett::{barrett_set, Barrett, BarrettError};
pub use crt::CrtContext;
pub use moduli::{
    default_moduli, fits_lane_width, generate_prime_moduli, is_pairwise_coprime,
    MAX_LANE_MODULUS_BITS,
};
pub use plane::ResiduePlane;
pub use residue::ResidueVec;
