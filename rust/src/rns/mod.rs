//! Residue Number System substrate (paper §II-D, §III-A).
//!
//! Residues are `u64` values below `u32`-sized moduli; channelwise modular
//! arithmetic uses Barrett reduction with precomputed constants — the same
//! "precomputed constants and structured reduction logic" the paper's RTL
//! uses (§VI-B) — and reconstruction goes through a precomputed CRT context
//! (or mixed-radix conversion for comparison-only paths).

pub mod moduli;
pub mod barrett;
pub mod residue;
pub mod crt;
pub mod plane;

pub use barrett::Barrett;
pub use crt::CrtContext;
pub use moduli::{default_moduli, generate_prime_moduli, is_pairwise_coprime};
pub use plane::ResiduePlane;
pub use residue::ResidueVec;
