//! Modulus-set selection and validation.
//!
//! HRFNA requires pairwise coprime moduli (paper §III-A); the default set is
//! the k=8 largest 16-bit primes, giving a composite modulus M ≈ 2^127.9 —
//! enough headroom for 64k-long FP32-scale multiply-accumulate chains
//! between normalization events.

/// Default modulus set — keep in sync with `python/tests/conftest.py`.
pub const DEFAULT_MODULI: [u64; 8] = [
    65521, 65519, 65497, 65479, 65449, 65447, 65437, 65423,
];

/// Lane-kernel modulus ceiling in bits. The deferred-reduction planar
/// kernels (`rns::plane`) multiply two residues with a plain `u64`
/// multiply (no widening) and accumulate the raw ≤ 62-bit products into
/// `u128` sums, folding to one Barrett reduction per
/// [`crate::rns::plane::DOT_FOLD_TERMS`] terms. Both steps require every
/// modulus to be at most 31 bits: products stay below `2^62` and a `u128`
/// accumulator holds `2^128 / 2^62 = 2^66` of them before it could wrap.
pub const MAX_LANE_MODULUS_BITS: u32 = 31;

/// True iff `m` is usable by the deferred lane kernels: `2 ≤ m < 2^31`.
pub fn fits_lane_width(m: u64) -> bool {
    (2..1u64 << MAX_LANE_MODULUS_BITS).contains(&m)
}

/// The default modulus set as a Vec.
pub fn default_moduli() -> Vec<u64> {
    DEFAULT_MODULI.to_vec()
}

/// Greatest common divisor.
pub fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a
}

/// True iff every pair of moduli is coprime (CRT requirement).
pub fn is_pairwise_coprime(moduli: &[u64]) -> bool {
    for i in 0..moduli.len() {
        for j in (i + 1)..moduli.len() {
            if gcd(moduli[i], moduli[j]) != 1 {
                return false;
            }
        }
    }
    true
}

/// Deterministic Miller–Rabin primality for u64 (bases valid for all u64).
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n == p {
            return true;
        }
        if n % p == 0 {
            return false;
        }
    }
    let mut d = n - 1;
    let mut r = 0u32;
    while d % 2 == 0 {
        d /= 2;
        r += 1;
    }
    'witness: for a in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..r - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// `(a * b) mod m` without overflow.
#[inline]
pub fn mul_mod(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

/// `(base ^ exp) mod m`.
pub fn pow_mod(mut base: u64, mut exp: u64, m: u64) -> u64 {
    let mut acc = 1u64;
    base %= m;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mul_mod(acc, base, m);
        }
        base = mul_mod(base, base, m);
        exp >>= 1;
    }
    acc
}

/// Generate `k` prime moduli descending from `2^width - 1` (primes are
/// automatically pairwise coprime). Panics if the width can't supply k
/// primes or if `width` exceeds [`MAX_LANE_MODULUS_BITS`] (the deferred
/// lane kernels accumulate raw 62-bit products; see that constant).
pub fn generate_prime_moduli(k: usize, width: u32) -> Vec<u64> {
    assert!(
        (4..=MAX_LANE_MODULUS_BITS).contains(&width),
        "width must be in 4..={MAX_LANE_MODULUS_BITS}"
    );
    let mut out = Vec::with_capacity(k);
    let mut candidate = (1u64 << width) - 1;
    let floor = 1u64 << (width - 1);
    while out.len() < k && candidate > floor {
        if is_prime(candidate) {
            out.push(candidate);
        }
        candidate -= 1;
    }
    assert!(
        out.len() == k,
        "not enough {width}-bit primes for k={k}"
    );
    out
}

/// Composite modulus M = Π m_i as BigUint.
pub fn composite_modulus(moduli: &[u64]) -> crate::bigint::BigUint {
    let mut m = crate::bigint::BigUint::one();
    for &mi in moduli {
        m = m.mul_u64(mi);
    }
    m
}

/// log2(M) — the dynamic range of the residue-domain integer space.
pub fn dynamic_range_bits(moduli: &[u64]) -> f64 {
    moduli.iter().map(|&m| (m as f64).log2()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_set_is_valid() {
        assert!(is_pairwise_coprime(&DEFAULT_MODULI));
        for &m in &DEFAULT_MODULI {
            assert!(is_prime(m), "{m} not prime");
            assert!(m < 1 << 16);
        }
        let bits = dynamic_range_bits(&DEFAULT_MODULI);
        assert!(bits > 127.0 && bits < 128.0, "bits={bits}");
    }

    #[test]
    fn gcd_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(17, 5), 1);
        assert_eq!(gcd(0, 7), 7);
    }

    #[test]
    fn coprimality_detects_shared_factor() {
        assert!(!is_pairwise_coprime(&[6, 9]));
        assert!(is_pairwise_coprime(&[8, 9, 5, 7, 11]));
    }

    #[test]
    fn primality_known_values() {
        for p in [2u64, 3, 65521, 4294967291, 2_147_483_647] {
            assert!(is_prime(p), "{p}");
        }
        for c in [1u64, 4, 65520, 4294967295, 561, 1105] {
            assert!(!is_prime(c), "{c}");
        }
    }

    #[test]
    fn generated_moduli_match_default() {
        assert_eq!(generate_prime_moduli(8, 16), DEFAULT_MODULI.to_vec());
    }

    #[test]
    fn generated_moduli_other_widths() {
        for width in [8u32, 12, 20, 31] {
            let ms = generate_prime_moduli(4, width);
            assert!(is_pairwise_coprime(&ms));
            for &m in &ms {
                assert!(m < 1 << width && m >= 1 << (width - 1));
            }
        }
    }

    #[test]
    fn lane_width_bounds() {
        assert!(!fits_lane_width(0));
        assert!(!fits_lane_width(1));
        assert!(fits_lane_width(2));
        assert!(fits_lane_width(65521));
        assert!(fits_lane_width((1 << 31) - 1));
        assert!(!fits_lane_width(1 << 31));
        assert!(!fits_lane_width((1 << 32) - 5));
        for &m in &DEFAULT_MODULI {
            assert!(fits_lane_width(m));
        }
    }

    #[test]
    #[should_panic(expected = "width must be in")]
    fn generate_width_32_rejected() {
        generate_prime_moduli(2, 32);
    }

    #[test]
    fn pow_mod_fermat() {
        // a^(p-1) ≡ 1 mod p for prime p
        for &p in &[65521u64, 65519] {
            for a in [2u64, 3, 12345] {
                assert_eq!(pow_mod(a, p - 1, p), 1);
            }
        }
    }

    #[test]
    fn composite_modulus_value() {
        let m = composite_modulus(&[3, 5, 7]);
        assert_eq!(m.to_u64(), Some(105));
    }
}
