//! Planar (structure-of-arrays) residue storage and the batched per-channel
//! kernels that run on it.
//!
//! A [`ResiduePlane`] holds a batch of `n` residue vectors as `k` contiguous
//! `u64` lanes, one per modulus: `lanes[c * n + j]` is channel `c` of element
//! `j`. This is the software mirror of the paper's hardware layout (one
//! modular pipeline per channel, §VI-B): each lane is walked by a tight,
//! allocation-free, auto-vectorizable loop instead of the pointer-chasing
//! per-element [`ResidueVec`] path, and it is the layout the AOT kernels
//! already use (`int64[k, n]` channel-major tensors).
//!
//! ## Deferred reduction
//!
//! The lane kernels run the paper's lazy-reduction discipline in software:
//! every modulus set is validated to ≤ 31 bits
//! ([`crate::rns::moduli::MAX_LANE_MODULUS_BITS`]), so a residue product is
//! one plain `u64` multiply (≤ 62 bits, no widening) and [`lane_dot`] /
//! [`lane_dot_scaled`] sum those raw products into `u128` accumulators,
//! folding to a **single** `Barrett` reduction per [`DOT_FOLD_TERMS`]
//! terms — one reduction per dot product for every realistic lane length,
//! instead of one per element. [`lane_fma`] reduces the raw 63-bit
//! `acc + x·y` once per element, and [`lane_scale`] streams a Shoup
//! multiply (mul-hi + mul-lo + one conditional subtract). The former
//! per-element kernels live on in [`reference`] and back the bit-identity
//! property tests.
//!
//! ## Runtime SIMD dispatch (`simd` feature)
//!
//! With `--features simd` on x86_64, every hot kernel ([`lane_mul`],
//! [`lane_scale`], [`lane_fma`], [`lane_dot`]/[`lane_dot_folded`],
//! [`lane_dot_scaled`]) is a thin dispatch shim: the wide-modulus check is
//! hoisted here (one branch per *call*, not per element), then the kernel
//! takes the AVX2 path from [`crate::rns::simd`] when the host CPU
//! reports AVX2 (`is_x86_feature_detected!`, probed once and cached) and
//! the scalar `*_scalar` kernel otherwise — one binary serves any host.
//! Scalar and SIMD variants are bit-identical (pinned by the property
//! suite below, including fold straddles and the ≥ 32-bit-modulus
//! fallback); [`simd_active`] reports which path calls are taking.
//!
//! The plane is pure residue data. Exponent and interval bookkeeping for a
//! batch of HRFNA values lives in [`crate::hybrid::batch::HrfnaBatch`],
//! which drives these kernels.

use super::barrett::Barrett;
use super::residue::ResidueVec;
use thiserror::Error;

/// Fold threshold for the deferred dot kernels: raw ≤ 62-bit products are
/// summed into `u128` accumulators and reduced once per this many terms.
/// A `u128` holds `2^128 / 2^62 = 2^66` such terms before it could wrap;
/// `2^32` keeps a deep safety margin (the striped partial sums stay below
/// `2^94`) while still meaning "one reduction per dot" for any lane that
/// fits in memory.
pub const DOT_FOLD_TERMS: usize = {
    const F: u64 = 1 << 32;
    if (usize::MAX as u64) < F {
        usize::MAX
    } else {
        F as usize
    }
};

/// Independent accumulator stripes per lane (ILP: the compiler can keep
/// four dependency chains in flight and vectorize the product loop).
const DOT_STRIPES: usize = 4;

/// Errors for fallible plane constructors.
#[derive(Clone, Debug, Error, PartialEq, Eq)]
pub enum PlaneError {
    /// Lane buffer length does not match `k * n`.
    #[error("lane data length {got} != k*n = {want}")]
    LaneLen { got: usize, want: usize },
    /// Two planes with different shapes were combined.
    #[error("plane shape mismatch: {0}x{1} vs {2}x{3}")]
    Shape(usize, usize, usize, usize),
}

/// A batch of residue vectors in channel-major planar layout.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ResiduePlane {
    k: usize,
    n: usize,
    lanes: Vec<u64>,
}

impl ResiduePlane {
    /// All-zero plane (a batch of `n` zero values over `k` channels).
    pub fn zero(k: usize, n: usize) -> ResiduePlane {
        ResiduePlane {
            k,
            n,
            lanes: vec![0; k * n],
        }
    }

    /// Wrap an existing channel-major lane buffer.
    pub fn from_lanes(k: usize, n: usize, lanes: Vec<u64>) -> Result<ResiduePlane, PlaneError> {
        if lanes.len() != k * n {
            return Err(PlaneError::LaneLen {
                got: lanes.len(),
                want: k * n,
            });
        }
        Ok(ResiduePlane { k, n, lanes })
    }

    /// Encode a batch of signed integers (M-complement per channel), with
    /// contiguous per-channel writes — the planar form of the block-encode
    /// inner loop (`coordinator::hybrid_exec::encode_block`).
    pub fn encode_signed(staged: &[i64], moduli: &[u64], bars: &[Barrett]) -> ResiduePlane {
        debug_assert_eq!(moduli.len(), bars.len());
        let k = moduli.len();
        let n = staged.len();
        let mut lanes = vec![0u64; k * n];
        for c in 0..k {
            let bar = bars[c];
            let m = moduli[c];
            let row = &mut lanes[c * n..(c + 1) * n];
            for (out, &s) in row.iter_mut().zip(staged) {
                let r = bar.reduce(s.unsigned_abs());
                *out = if s < 0 && r != 0 { m - r } else { r };
            }
        }
        ResiduePlane { k, n, lanes }
    }

    /// The [`ResiduePlane::encode_signed`] lane loop writing straight into
    /// an `i64` channel-major buffer — the PJRT tensor form. One pass, no
    /// intermediate plane (the serving hot path's block encode).
    pub fn encode_signed_i64(staged: &[i64], moduli: &[u64], bars: &[Barrett]) -> Vec<i64> {
        debug_assert_eq!(moduli.len(), bars.len());
        let k = moduli.len();
        let n = staged.len();
        let mut lanes = vec![0i64; k * n];
        for c in 0..k {
            let bar = bars[c];
            let m = moduli[c];
            let row = &mut lanes[c * n..(c + 1) * n];
            for (out, &s) in row.iter_mut().zip(staged) {
                let r = bar.reduce(s.unsigned_abs());
                *out = if s < 0 && r != 0 { (m - r) as i64 } else { r as i64 };
            }
        }
        lanes
    }

    /// Number of channels.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of elements.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// One channel's contiguous lane.
    #[inline]
    pub fn lane(&self, c: usize) -> &[u64] {
        &self.lanes[c * self.n..(c + 1) * self.n]
    }

    /// Mutable lane access.
    #[inline]
    pub fn lane_mut(&mut self, c: usize) -> &mut [u64] {
        &mut self.lanes[c * self.n..(c + 1) * self.n]
    }

    /// The raw channel-major buffer.
    #[inline]
    pub fn lanes(&self) -> &[u64] {
        &self.lanes
    }

    /// Mutable access to the raw channel-major buffer (the normalization
    /// engine rescales a gathered scratch plane in place).
    #[inline]
    pub fn lanes_mut(&mut self) -> &mut [u64] {
        &mut self.lanes
    }

    /// Gather the columns `idx` into a dense `k × idx.len()` scratch
    /// plane (channel-major, so the batched-CRT/rescale kernels stream
    /// it contiguously). The flagged-column gather of the bulk
    /// normalization engine: at low flagged densities the expensive
    /// per-element reconstruction work runs over a compact plane instead
    /// of strided hops across the full batch.
    pub fn gather_columns(&self, idx: &[usize]) -> ResiduePlane {
        let w = idx.len();
        let mut lanes = vec![0u64; self.k * w];
        for c in 0..self.k {
            gather_lane(self.lane(c), idx, &mut lanes[c * w..(c + 1) * w]);
        }
        ResiduePlane { k: self.k, n: w, lanes }
    }

    /// Scatter a dense scratch plane (as produced by
    /// [`ResiduePlane::gather_columns`]) back into the columns `idx`.
    pub fn scatter_columns(&mut self, idx: &[usize], scratch: &ResiduePlane) {
        debug_assert_eq!(scratch.k, self.k);
        debug_assert_eq!(scratch.n, idx.len());
        for c in 0..self.k {
            scatter_lane(
                &mut self.lanes[c * self.n..(c + 1) * self.n],
                idx,
                scratch.lane(c),
            );
        }
    }

    /// Gather element `j` across channels into a [`ResidueVec`].
    pub fn get(&self, j: usize) -> ResidueVec {
        ResidueVec {
            r: (0..self.k).map(|c| self.lanes[c * self.n + j]).collect(),
        }
    }

    /// Scatter a [`ResidueVec`] into element `j`.
    pub fn set(&mut self, j: usize, r: &ResidueVec) {
        debug_assert_eq!(r.k(), self.k);
        for (c, &v) in r.r.iter().enumerate() {
            self.lanes[c * self.n + j] = v;
        }
    }

    /// Elementwise modular multiplication (lane-parallel Definition 2).
    pub fn mul(&self, other: &ResiduePlane, bars: &[Barrett]) -> ResiduePlane {
        debug_assert_eq!((self.k, self.n), (other.k, other.n));
        let mut out = ResiduePlane::zero(self.k, self.n);
        for c in 0..self.k {
            lane_mul(bars[c], self.lane(c), other.lane(c), out.lane_mut(c));
        }
        out
    }

    /// Elementwise modular addition.
    pub fn add(&self, other: &ResiduePlane, bars: &[Barrett]) -> ResiduePlane {
        debug_assert_eq!((self.k, self.n), (other.k, other.n));
        let mut out = ResiduePlane::zero(self.k, self.n);
        for c in 0..self.k {
            lane_add(bars[c], self.lane(c), other.lane(c), out.lane_mut(c));
        }
        out
    }

    /// Elementwise M-complement negation.
    pub fn neg(&self, moduli: &[u64]) -> ResiduePlane {
        let mut out = ResiduePlane::zero(self.k, self.n);
        for c in 0..self.k {
            lane_neg(moduli[c], self.lane(c), out.lane_mut(c));
        }
        out
    }

    /// In-place fused multiply-accumulate: `self[c][j] += x[c][j] * y[c][j]`
    /// per channel — the planar hot loop of Algorithm 1, on the deferred
    /// [`lane_fma`] kernel (one reduction per element, no modular add).
    pub fn fma_assign(&mut self, x: &ResiduePlane, y: &ResiduePlane, bars: &[Barrett]) {
        debug_assert_eq!((self.k, self.n), (x.k, x.n));
        debug_assert_eq!((self.k, self.n), (y.k, y.n));
        let n = self.n;
        for c in 0..self.k {
            let acc = &mut self.lanes[c * n..(c + 1) * n];
            let xs = &x.lanes[c * n..(c + 1) * n];
            let ys = &y.lanes[c * n..(c + 1) * n];
            lane_fma(bars[c], acc, xs, ys);
        }
    }

    /// Per-channel scaling by a key residue: `out[c][j] = α_c·self[c][j]
    /// mod m_c` — the MAC-lane derivation of the authenticated serving
    /// path (`mac(x) = α·x` per channel, [`crate::hybrid::auth`]). One
    /// [`lane_scale`] Shoup pass per channel; `alpha[c] < m_c` required.
    pub fn scale_channels(&self, alpha: &[u64], bars: &[Barrett]) -> ResiduePlane {
        debug_assert_eq!(alpha.len(), self.k);
        debug_assert_eq!(bars.len(), self.k);
        let mut out = ResiduePlane::zero(self.k, self.n);
        for c in 0..self.k {
            lane_scale(bars[c], self.lane(c), alpha[c], out.lane_mut(c));
        }
        out
    }

    /// True per element iff any channel residue is nonzero (i.e. the
    /// represented integer is nonzero). One contiguous pass per lane.
    pub fn nonzero_mask(&self) -> Vec<bool> {
        let mut nz = vec![false; self.n];
        for c in 0..self.k {
            for (flag, &v) in nz.iter_mut().zip(self.lane(c)) {
                *flag |= v != 0;
            }
        }
        nz
    }
}

/// True iff lane-kernel calls are currently taking the AVX2 SIMD path:
/// the `simd` feature is compiled in, the target is x86_64 and the host
/// CPU reports AVX2 at runtime. Scalar and SIMD paths are bit-identical —
/// this is observability for benches and tests, not a correctness switch.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn simd_active() -> bool {
    super::simd::avx2_available()
}

/// `simd` feature off (or non-x86_64 target): the dispatch shims always
/// take the scalar kernels.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn simd_active() -> bool {
    false
}

/// `out[t] = src[idx[t]]` over one lane — the flagged-column gather of
/// the bulk normalization engine as a standalone kernel. Dispatch shim:
/// the AVX2 hardware gather (`vpgatherqq`) when compiled in and
/// available, else [`gather_lane_scalar`]. Pure `u64` movement, so
/// there is no modulus gate; the SIMD arm additionally requires every
/// index in bounds (an out-of-range index falls back to the scalar
/// kernel, which panics on the bad access exactly as before).
#[inline]
pub fn gather_lane(src: &[u64], idx: &[usize], out: &mut [u64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if super::simd::avx2_available() && idx.iter().all(|&j| j < src.len()) {
            // SAFETY: AVX2 support and index bounds were just verified.
            unsafe { super::simd::gather_lane_avx2(src, idx, out) };
            return;
        }
    }
    gather_lane_scalar(src, idx, out)
}

/// Scalar `out[t] = src[idx[t]]`.
#[inline]
pub fn gather_lane_scalar(src: &[u64], idx: &[usize], out: &mut [u64]) {
    for (o, &j) in out.iter_mut().zip(idx) {
        *o = src[j];
    }
}

/// `dst[idx[t]] = src[t]` over one lane — the inverse of
/// [`gather_lane`]. Dispatch shim over [`scatter_lane_scalar`] and the
/// AVX2 kernel (vectorized source loads + in-order indexed stores, so
/// duplicate indices resolve last-write-wins identically on both
/// paths).
#[inline]
pub fn scatter_lane(dst: &mut [u64], idx: &[usize], src: &[u64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if super::simd::avx2_available() && idx.iter().all(|&j| j < dst.len()) {
            // SAFETY: AVX2 support and index bounds were just verified.
            unsafe { super::simd::scatter_lane_avx2(dst, idx, src) };
            return;
        }
    }
    scatter_lane_scalar(dst, idx, src)
}

/// Scalar `dst[idx[t]] = src[t]`.
#[inline]
pub fn scatter_lane_scalar(dst: &mut [u64], idx: &[usize], src: &[u64]) {
    for (&j, &v) in idx.iter().zip(src) {
        dst[j] = v;
    }
}

/// `out[j] = (x[j] * y[j]) mod m` over one lane. Dispatch shim: AVX2 when
/// compiled in and available (lane-width moduli only), else the scalar
/// Barrett kernel [`lane_mul_scalar`].
#[inline]
pub fn lane_mul(bar: Barrett, x: &[u64], y: &[u64], out: &mut [u64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if bar.deferred_ok() && super::simd::avx2_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { super::simd::lane_mul_avx2(bar, x, y, out) };
            return;
        }
    }
    lane_mul_scalar(bar, x, y, out)
}

/// Scalar `out[j] = (x[j] * y[j]) mod m` (branch-free Barrett: mul-hi
/// quotient estimate, mul-lo remainder, conditional subtract).
#[inline]
pub fn lane_mul_scalar(bar: Barrett, x: &[u64], y: &[u64], out: &mut [u64]) {
    for ((o, &a), &b) in out.iter_mut().zip(x).zip(y) {
        *o = bar.mul(a, b);
    }
}

/// `out[j] = (x[j] + y[j]) mod m` over one lane.
#[inline]
pub fn lane_add(bar: Barrett, x: &[u64], y: &[u64], out: &mut [u64]) {
    for ((o, &a), &b) in out.iter_mut().zip(x).zip(y) {
        *o = bar.add(a, b);
    }
}

/// `out[j] = (m - x[j]) mod m` over one lane (M-complement negation).
#[inline]
pub fn lane_neg(m: u64, x: &[u64], out: &mut [u64]) {
    for (o, &a) in out.iter_mut().zip(x) {
        *o = if a == 0 { 0 } else { m - a };
    }
}

/// `out[j] = (x[j] * mult) mod m` over one lane (residue-domain scaling,
/// e.g. by a precomputed `2^Δ mod m`). Dispatch shim over
/// [`lane_scale_scalar`] and the AVX2 Shoup kernel. Requires `mult < m`.
#[inline]
pub fn lane_scale(bar: Barrett, x: &[u64], mult: u64, out: &mut [u64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if bar.deferred_ok() && super::simd::avx2_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { super::simd::lane_scale_avx2(bar, x, mult, out) };
            return;
        }
    }
    lane_scale_scalar(bar, x, mult, out)
}

/// Scalar `out[j] = (x[j] * mult) mod m`: the Shoup constant for `mult`
/// is precomputed once, making the loop body a mul-hi + mul-lo pair + one
/// conditional subtract. Requires `mult < m`.
#[inline]
pub fn lane_scale_scalar(bar: Barrett, x: &[u64], mult: u64, out: &mut [u64]) {
    let shoup = bar.shoup(mult);
    for (o, &a) in out.iter_mut().zip(x) {
        *o = bar.mul_shoup(a, mult, shoup);
    }
}

/// `acc[j] = (acc[j] + x[j]*y[j]) mod m` over one lane. Dispatch shim:
/// the wide-modulus check is hoisted here — [`reference::lane_fma`] for
/// moduli outside the lane-width invariant, decided once per call instead
/// of branching in the loop prelude — then AVX2 or [`lane_fma_scalar`].
#[inline]
pub fn lane_fma(bar: Barrett, acc: &mut [u64], x: &[u64], y: &[u64]) {
    if !bar.deferred_ok() {
        return reference::lane_fma(bar, acc, x, y);
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if super::simd::avx2_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { super::simd::lane_fma_avx2(bar, acc, x, y) };
            return;
        }
    }
    lane_fma_scalar(bar, acc, x, y)
}

/// Scalar deferred FMA: the raw ≤ 62-bit product plus the ≤ 31-bit
/// accumulator fits 63 bits, so one Barrett reduction per element
/// replaces the former reduce-then-modular-add pair. Lane-width moduli
/// only (the dispatch shim [`lane_fma`] routes wide moduli to the
/// reference kernel).
#[inline]
pub fn lane_fma_scalar(bar: Barrett, acc: &mut [u64], x: &[u64], y: &[u64]) {
    debug_assert!(bar.deferred_ok());
    for ((a, &xv), &yv) in acc.iter_mut().zip(x).zip(y) {
        *a = bar.reduce(*a + xv * yv);
    }
}

/// Modular dot product over one lane: `Σ_j x[j]·y[j] mod m`, via deferred
/// reduction with the default fold threshold ([`DOT_FOLD_TERMS`]) — a
/// single reduction for any realistic `n`.
#[inline]
pub fn lane_dot(bar: Barrett, x: &[u64], y: &[u64]) -> u64 {
    lane_dot_folded(bar, x, y, DOT_FOLD_TERMS)
}

/// [`lane_dot`] with an explicit fold threshold, as a dispatch shim:
/// wide moduli fall back to [`reference::lane_dot`], lane-width moduli
/// take the AVX2 kernel when compiled in and available, else
/// [`lane_dot_folded_scalar`]. Exposed so property tests and benches can
/// straddle the fold boundary with small thresholds; the result is
/// bit-identical to [`reference::lane_dot`] for every `fold` on every
/// path.
pub fn lane_dot_folded(bar: Barrett, x: &[u64], y: &[u64], fold: usize) -> u64 {
    if !bar.deferred_ok() {
        return reference::lane_dot(bar, x, y);
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if super::simd::avx2_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            return unsafe { super::simd::lane_dot_folded_avx2(bar, x, y, fold) };
        }
    }
    lane_dot_folded_scalar(bar, x, y, fold)
}

/// The [`lane_dot`] dispatch shim with the SIMD arm compiled out: hoisted
/// wide-modulus check + [`lane_dot_folded_scalar`] — exactly what
/// [`lane_dot`] compiles to without the `simd` feature (or on a host
/// without AVX2). A named entry point so `bench_kernels` can pin the
/// dispatch-shim overhead (≤ 1.05× the raw scalar kernel) in every build
/// flavor.
pub fn lane_dot_dispatch_scalar(bar: Barrett, x: &[u64], y: &[u64]) -> u64 {
    if !bar.deferred_ok() {
        return reference::lane_dot(bar, x, y);
    }
    lane_dot_folded_scalar(bar, x, y, DOT_FOLD_TERMS)
}

/// Scalar deferred dot with the default fold threshold. Lane-width
/// moduli only (dispatch shims route wide moduli to the reference
/// kernel).
#[inline]
pub fn lane_dot_scalar(bar: Barrett, x: &[u64], y: &[u64]) -> u64 {
    lane_dot_folded_scalar(bar, x, y, DOT_FOLD_TERMS)
}

/// Scalar [`lane_dot_folded`]: raw products accumulate into
/// [`DOT_STRIPES`] independent `u128` sums and fold to one
/// `Barrett::reduce_u128` every `fold` terms.
pub fn lane_dot_folded_scalar(bar: Barrett, x: &[u64], y: &[u64], fold: usize) -> u64 {
    debug_assert!(bar.deferred_ok());
    let n = x.len().min(y.len());
    let (x, y) = (&x[..n], &y[..n]);
    let fold = fold.clamp(1, DOT_FOLD_TERMS);
    let mut acc = 0u64;
    for (xc, yc) in x.chunks(fold).zip(y.chunks(fold)) {
        let mut s = [0u128; DOT_STRIPES];
        let mut xs = xc.chunks_exact(DOT_STRIPES);
        let mut ys = yc.chunks_exact(DOT_STRIPES);
        for (qx, qy) in (&mut xs).zip(&mut ys) {
            s[0] += (qx[0] * qy[0]) as u128;
            s[1] += (qx[1] * qy[1]) as u128;
            s[2] += (qx[2] * qy[2]) as u128;
            s[3] += (qx[3] * qy[3]) as u128;
        }
        let mut tail = 0u128;
        for (&a, &b) in xs.remainder().iter().zip(ys.remainder()) {
            tail += (a * b) as u128;
        }
        // Each stripe holds ≤ fold/4 ≤ 2^30 terms below 2^62: the combined
        // sum stays below 2^94, far from the u128 edge.
        let total = s[0] + s[1] + s[2] + s[3] + tail;
        acc = bar.add(acc, bar.reduce_u128(total));
    }
    acc
}

/// Modular dot product with a per-element scale factor:
/// `Σ_j x[j]·y[j]·mults[j] mod m` — the exponent-aligned accumulation of
/// Algorithm 1 with `mults[j] = 2^{Δ_j} mod m`. Dispatch shim: wide
/// moduli fall back to [`reference::lane_dot_scaled`], lane-width moduli
/// take AVX2 when compiled in and available, else
/// [`lane_dot_scaled_scalar`].
pub fn lane_dot_scaled(bar: Barrett, x: &[u64], y: &[u64], mults: &[u64]) -> u64 {
    if !bar.deferred_ok() {
        return reference::lane_dot_scaled(bar, x, y, mults);
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if super::simd::avx2_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            return unsafe { super::simd::lane_dot_scaled_avx2(bar, x, y, mults) };
        }
    }
    lane_dot_scaled_scalar(bar, x, y, mults)
}

/// Scalar deferred scaled dot: one reduction brings the 62-bit product
/// back under `m`, the third factor stays raw in the `u128` accumulator,
/// and the fold pays the second reduction once per [`DOT_FOLD_TERMS`]
/// terms.
pub fn lane_dot_scaled_scalar(bar: Barrett, x: &[u64], y: &[u64], mults: &[u64]) -> u64 {
    debug_assert!(bar.deferred_ok());
    let n = x.len().min(y.len()).min(mults.len());
    let (x, y, mults) = (&x[..n], &y[..n], &mults[..n]);
    let mut acc = 0u64;
    for ((xc, yc), sc) in x
        .chunks(DOT_FOLD_TERMS)
        .zip(y.chunks(DOT_FOLD_TERMS))
        .zip(mults.chunks(DOT_FOLD_TERMS))
    {
        let mut sum = 0u128;
        for ((&a, &b), &s) in xc.iter().zip(yc).zip(sc) {
            sum += (bar.reduce(a * b) * s) as u128;
        }
        acc = bar.add(acc, bar.reduce_u128(sum));
    }
    acc
}

/// The per-element reference kernels: one reduction (and one modular
/// add) per element — naive widening `%` where that makes the check
/// independent. Kept as the executable specification — the deferred
/// kernels above are property-tested bit-identical to these — and as the
/// fallback for moduli outside the 31-bit lane invariant.
pub mod reference {
    use super::Barrett;

    /// Per-element `out[j] = (x[j] * y[j]) mod m` via naive widening
    /// arithmetic (`u128` multiply + `%`) — an *independent*
    /// specification of the elementwise product, so the bit-identity test
    /// genuinely checks the Barrett path rather than comparing it to
    /// itself.
    #[inline]
    pub fn lane_mul(bar: Barrett, x: &[u64], y: &[u64], out: &mut [u64]) {
        let m = bar.m as u128;
        for ((o, &a), &b) in out.iter_mut().zip(x).zip(y) {
            *o = ((a as u128 * b as u128) % m) as u64;
        }
    }

    /// Per-element `out[j] = (x[j] * mult) mod m` (full Barrett per step).
    #[inline]
    pub fn lane_scale(bar: Barrett, x: &[u64], mult: u64, out: &mut [u64]) {
        for (o, &a) in out.iter_mut().zip(x) {
            *o = bar.mul(a, mult);
        }
    }

    /// Per-element-reducing dot: `acc = (acc + reduce(x·y)) mod m` each
    /// step.
    #[inline]
    pub fn lane_dot(bar: Barrett, x: &[u64], y: &[u64]) -> u64 {
        let mut acc = 0u64;
        for (&a, &b) in x.iter().zip(y) {
            acc = bar.add(acc, bar.mul(a, b));
        }
        acc
    }

    /// Per-element-reducing scaled dot (two reductions + one add per
    /// element).
    #[inline]
    pub fn lane_dot_scaled(bar: Barrett, x: &[u64], y: &[u64], mults: &[u64]) -> u64 {
        let mut acc = 0u64;
        for ((&a, &b), &s) in x.iter().zip(y).zip(mults) {
            acc = bar.add(acc, bar.mul(bar.mul(a, b), s));
        }
        acc
    }

    /// Per-element `acc[j] = (acc[j] + x[j]·y[j]) mod m` (reduce + modular
    /// add per element).
    #[inline]
    pub fn lane_fma(bar: Barrett, acc: &mut [u64], x: &[u64], y: &[u64]) {
        for ((a, &xv), &yv) in acc.iter_mut().zip(x).zip(y) {
            *a = bar.add(*a, bar.mul(xv, yv));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::barrett::barrett_set;
    use crate::rns::moduli::DEFAULT_MODULI;
    use crate::util::proptest::check_with;
    use crate::util::prng::Rng;

    fn bars() -> Vec<Barrett> {
        barrett_set(&DEFAULT_MODULI)
    }

    fn random_plane(rng: &mut Rng, n: usize) -> ResiduePlane {
        let k = DEFAULT_MODULI.len();
        let mut p = ResiduePlane::zero(k, n);
        for c in 0..k {
            let m = DEFAULT_MODULI[c];
            for v in p.lane_mut(c) {
                *v = rng.below(m);
            }
        }
        p
    }

    fn random_lane(rng: &mut Rng, m: u64, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.below(m)).collect()
    }

    #[test]
    fn from_lanes_validates_shape() {
        assert!(ResiduePlane::from_lanes(2, 3, vec![0; 6]).is_ok());
        assert_eq!(
            ResiduePlane::from_lanes(2, 3, vec![0; 5]),
            Err(PlaneError::LaneLen { got: 5, want: 6 })
        );
    }

    #[test]
    fn get_set_roundtrip() {
        let mut p = ResiduePlane::zero(8, 4);
        let r = ResidueVec::encode_u64(123_456_789, &DEFAULT_MODULI);
        p.set(2, &r);
        assert_eq!(p.get(2), r);
        assert!(p.get(0).is_zero());
        let nz = p.nonzero_mask();
        assert_eq!(nz, vec![false, false, true, false]);
    }

    #[test]
    fn encode_signed_matches_scalar_encode() {
        let b = bars();
        let staged: Vec<i64> = vec![0, 1, -1, 42, -65521, 65524, i64::MAX, i64::MIN + 1];
        let p = ResiduePlane::encode_signed(&staged, &DEFAULT_MODULI, &b);
        for (j, &s) in staged.iter().enumerate() {
            let want: Vec<u64> = DEFAULT_MODULI
                .iter()
                .map(|&m| {
                    let r = s.unsigned_abs() % m;
                    if s < 0 && r != 0 {
                        m - r
                    } else {
                        r
                    }
                })
                .collect();
            assert_eq!(p.get(j).r, want, "j={j} s={s}");
        }
    }

    #[test]
    fn encode_signed_i64_matches_plane_encode() {
        let b = bars();
        let staged: Vec<i64> = vec![0, 7, -7, 65520, -65522, 1 << 40, -(1 << 40)];
        let plane = ResiduePlane::encode_signed(&staged, &DEFAULT_MODULI, &b);
        let lanes = ResiduePlane::encode_signed_i64(&staged, &DEFAULT_MODULI, &b);
        assert_eq!(lanes.len(), plane.lanes().len());
        for (a, &u) in lanes.iter().zip(plane.lanes()) {
            assert_eq!(*a, u as i64);
        }
    }

    #[test]
    fn gather_scatter_columns_roundtrip() {
        let mut rng = Rng::new(3);
        let mut p = random_plane(&mut rng, 11);
        let idx = [1usize, 4, 9, 10];
        let scratch = p.gather_columns(&idx);
        assert_eq!(scratch.k(), p.k());
        assert_eq!(scratch.n(), idx.len());
        for (t, &j) in idx.iter().enumerate() {
            assert_eq!(scratch.get(t), p.get(j), "gathered column {j}");
        }
        // Mutate the scratch and scatter back: exactly the chosen
        // columns change, everything else is untouched.
        let before = p.clone();
        let mut edited = scratch.clone();
        for c in 0..edited.k() {
            let m = DEFAULT_MODULI[c];
            for v in edited.lane_mut(c) {
                *v = (*v + 1) % m;
            }
        }
        p.scatter_columns(&idx, &edited);
        for j in 0..p.n() {
            if let Some(t) = idx.iter().position(|&x| x == j) {
                assert_eq!(p.get(j), edited.get(t), "scattered column {j}");
            } else {
                assert_eq!(p.get(j), before.get(j), "untouched column {j}");
            }
        }
        // Empty gather is a 0-column plane; scattering it is a no-op.
        let empty = p.gather_columns(&[]);
        assert_eq!(empty.n(), 0);
        let snapshot = p.clone();
        p.scatter_columns(&[], &empty);
        assert_eq!(p, snapshot);
    }

    #[test]
    fn prop_gather_scatter_dispatch_bit_identical_to_scalar() {
        // The gather/scatter shims are pure u64 movement: random lane
        // data (full u64 range — no modulus involved), widths covering
        // 0 / 1 / odd / 4-multiple shapes, and indices with duplicates
        // (scatter must resolve them last-write-wins on both paths).
        check_with("gather-scatter-dispatch", 64, |rng| {
            let n = 1 + rng.below(65) as usize;
            let w = match rng.below(4) {
                0 => 0,
                1 => 1,
                2 => 1 + 2 * rng.below(16) as usize,
                _ => 4 * (1 + rng.below(8) as usize),
            };
            let src = random_lane(rng, u64::MAX, n);
            let idx: Vec<usize> = (0..w).map(|_| rng.below(n as u64) as usize).collect();
            let mut out_d = vec![0u64; w];
            let mut out_s = vec![0u64; w];
            gather_lane(&src, &idx, &mut out_d);
            gather_lane_scalar(&src, &idx, &mut out_s);
            crate::prop_assert!(out_d == out_s, "gather n={n} w={w}");
            let mut dst_d = random_lane(rng, u64::MAX, n);
            let mut dst_s = dst_d.clone();
            let vals = random_lane(rng, u64::MAX, w);
            scatter_lane(&mut dst_d, &idx, &vals);
            scatter_lane_scalar(&mut dst_s, &idx, &vals);
            crate::prop_assert!(dst_d == dst_s, "scatter n={n} w={w}");
            // Round trip through the dispatched pair restores exactly
            // the gathered columns.
            let mut back = dst_s.clone();
            let mut cols = vec![0u64; w];
            gather_lane(&dst_d, &idx, &mut cols);
            scatter_lane(&mut back, &idx, &cols);
            crate::prop_assert!(back == dst_d, "roundtrip n={n} w={w}");
            Ok(())
        });
    }

    #[test]
    fn prop_plane_ops_match_residuevec_ops() {
        let b = bars();
        check_with("plane-vs-residuevec", 64, |rng| {
            let n = 1 + rng.below(33) as usize;
            let x = random_plane(rng, n);
            let y = random_plane(rng, n);
            let mul = x.mul(&y, &b);
            let add = x.add(&y, &b);
            let neg = x.neg(&DEFAULT_MODULI);
            let mut fma = x.clone();
            fma.fma_assign(&x, &y, &b);
            for j in 0..n {
                let xv = x.get(j);
                let yv = y.get(j);
                crate::prop_assert!(mul.get(j) == xv.mul(&yv, &b), "mul j={j}");
                crate::prop_assert!(add.get(j) == xv.add(&yv, &b), "add j={j}");
                let mut mac = xv.clone();
                mac.mac_assign(&xv, &yv, &b);
                crate::prop_assert!(fma.get(j) == mac, "fma j={j}");
                let nv = neg.get(j);
                crate::prop_assert!(
                    xv.add(&nv, &b).is_zero(),
                    "neg is not the additive inverse j={j}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn lane_dot_matches_sequential_mac() {
        let b = bars();
        let mut rng = Rng::new(9);
        let n = 257;
        let x = random_plane(&mut rng, n);
        let y = random_plane(&mut rng, n);
        for c in 0..x.k() {
            let bar = b[c];
            let mut want = 0u64;
            for j in 0..n {
                want = bar.add(want, bar.mul(x.lane(c)[j], y.lane(c)[j]));
            }
            assert_eq!(lane_dot(bar, x.lane(c), y.lane(c)), want, "c={c}");
            // Scaled variant with all-ones multipliers degenerates to dot.
            let ones = vec![1u64; n];
            assert_eq!(
                lane_dot_scaled(bar, x.lane(c), y.lane(c), &ones),
                want,
                "scaled c={c}"
            );
        }
    }

    #[test]
    fn scale_channels_matches_pointwise_key_multiply() {
        let b = bars();
        let mut rng = Rng::new(31);
        let x = random_plane(&mut rng, 23);
        let alpha: Vec<u64> = DEFAULT_MODULI.iter().map(|&m| 1 + rng.below(m - 1)).collect();
        let mac = x.scale_channels(&alpha, &b);
        for c in 0..x.k() {
            for j in 0..x.n() {
                assert_eq!(mac.lane(c)[j], b[c].mul(alpha[c], x.lane(c)[j]), "c={c} j={j}");
            }
        }
    }

    #[test]
    fn lane_scale_matches_pointwise() {
        let b = bars();
        let mut rng = Rng::new(11);
        let x = random_plane(&mut rng, 17);
        for c in 0..x.k() {
            let mult = rng.below(DEFAULT_MODULI[c]);
            let mut out = vec![0u64; 17];
            lane_scale(b[c], x.lane(c), mult, &mut out);
            for (o, &xv) in out.iter().zip(x.lane(c)) {
                assert_eq!(*o, b[c].mul(xv, mult));
            }
        }
    }

    #[test]
    fn prop_deferred_kernels_bit_identical_to_reference() {
        // Random lane-width moduli (full 2..2^31 range), lengths covering
        // 0 / 1 / odd / stripe-remainder shapes, random residues: every
        // deferred kernel must agree with its per-element reference bit
        // for bit.
        check_with("deferred-vs-reference", 96, |rng| {
            let m = rng.below((1u64 << 31) - 2) + 2;
            let bar = Barrett::try_new(m).expect("lane-width modulus");
            let n = match rng.below(6) {
                0 => 0,
                1 => 1,
                2 => 2,
                3 => 1 + 2 * rng.below(16) as usize, // odd
                4 => 4 * (1 + rng.below(8) as usize), // stripe-aligned
                _ => 1 + rng.below(257) as usize,
            };
            let x = random_lane(rng, m, n);
            let y = random_lane(rng, m, n);
            let mults = random_lane(rng, m, n);
            crate::prop_assert!(
                lane_dot(bar, &x, &y) == reference::lane_dot(bar, &x, &y),
                "lane_dot m={m} n={n}"
            );
            crate::prop_assert!(
                lane_dot_scaled(bar, &x, &y, &mults)
                    == reference::lane_dot_scaled(bar, &x, &y, &mults),
                "lane_dot_scaled m={m} n={n}"
            );
            let mut acc_def = random_lane(rng, m, n);
            let mut acc_ref = acc_def.clone();
            lane_fma(bar, &mut acc_def, &x, &y);
            reference::lane_fma(bar, &mut acc_ref, &x, &y);
            crate::prop_assert!(acc_def == acc_ref, "lane_fma m={m} n={n}");
            let mult = rng.below(m);
            let mut out_def = vec![0u64; n];
            let mut out_ref = vec![0u64; n];
            lane_scale(bar, &x, mult, &mut out_def);
            reference::lane_scale(bar, &x, mult, &mut out_ref);
            crate::prop_assert!(out_def == out_ref, "lane_scale m={m} n={n}");
            let mut mul_def = vec![0u64; n];
            let mut mul_ref = vec![0u64; n];
            lane_mul(bar, &x, &y, &mut mul_def);
            reference::lane_mul(bar, &x, &y, &mut mul_ref);
            crate::prop_assert!(mul_def == mul_ref, "lane_mul m={m} n={n}");
            // Every (dispatched, scalar) pair must also agree bit for
            // bit: with the simd feature on an AVX2 host the dispatched
            // kernel is the SIMD variant and this genuinely pins
            // (SIMD, scalar); in every other build flavor it pins the
            // shim against the kernel it wraps.
            crate::prop_assert!(
                lane_dot(bar, &x, &y) == lane_dot_scalar(bar, &x, &y),
                "lane_dot dispatch-vs-scalar m={m} n={n}"
            );
            crate::prop_assert!(
                lane_dot_scaled(bar, &x, &y, &mults)
                    == lane_dot_scaled_scalar(bar, &x, &y, &mults),
                "lane_dot_scaled dispatch-vs-scalar m={m} n={n}"
            );
            let mut acc_sc = acc_ref.clone();
            let mut acc_disp = acc_ref.clone();
            lane_fma_scalar(bar, &mut acc_sc, &x, &y);
            lane_fma(bar, &mut acc_disp, &x, &y);
            crate::prop_assert!(acc_disp == acc_sc, "lane_fma dispatch-vs-scalar m={m} n={n}");
            let mut out_sc = vec![0u64; n];
            lane_scale_scalar(bar, &x, mult, &mut out_sc);
            crate::prop_assert!(out_def == out_sc, "lane_scale dispatch-vs-scalar m={m} n={n}");
            let mut mul_sc = vec![0u64; n];
            lane_mul_scalar(bar, &x, &y, &mut mul_sc);
            crate::prop_assert!(mul_def == mul_sc, "lane_mul dispatch-vs-scalar m={m} n={n}");
            Ok(())
        });
    }

    #[test]
    fn prop_fold_boundaries_bit_identical() {
        // Lengths straddling the fold threshold (n = fold-1, fold, fold+1,
        // multiples ± 1) must agree with the unfolded reference — the
        // partial-fold and cross-chunk carry logic is exactly what a big
        // threshold never exercises in-tests.
        check_with("deferred-fold-boundaries", 64, |rng| {
            let m = rng.below((1u64 << 31) - 2) + 2;
            let bar = Barrett::try_new(m).expect("lane-width modulus");
            let fold = 1 + rng.below(9) as usize; // 1..=9, straddles stripes
            for n in [
                fold.saturating_sub(1),
                fold,
                fold + 1,
                2 * fold - 1,
                2 * fold,
                2 * fold + 1,
                5 * fold + 3,
            ] {
                let x = random_lane(rng, m, n);
                let y = random_lane(rng, m, n);
                crate::prop_assert!(
                    lane_dot_folded(bar, &x, &y, fold) == reference::lane_dot(bar, &x, &y),
                    "fold={fold} n={n} m={m}"
                );
                // The dispatched fold (SIMD on an AVX2 simd build) must
                // agree with the scalar fold at every straddle shape —
                // the SIMD kernel re-associates only within a chunk, so
                // any chunk-boundary drift would show up exactly here.
                crate::prop_assert!(
                    lane_dot_folded(bar, &x, &y, fold)
                        == lane_dot_folded_scalar(bar, &x, &y, fold),
                    "dispatch-vs-scalar fold={fold} n={n} m={m}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn deferred_dot_huge_lane_and_worst_case_residues() {
        // A long lane of worst-case residues (all m-1): the largest
        // possible raw products, exercising the accumulator headroom
        // argument at the scale the serving path actually runs.
        let m = (1u64 << 31) - 1;
        let bar = Barrett::try_new(m).unwrap();
        let n = 65_536;
        let x = vec![m - 1; n];
        let y = vec![m - 1; n];
        // Σ (m-1)² mod m == Σ 1 mod m == n mod m.
        assert_eq!(lane_dot(bar, &x, &y), n as u64 % m);
        assert_eq!(lane_dot(bar, &x, &y), reference::lane_dot(bar, &x, &y));
        // And with a mid-lane fold.
        assert_eq!(
            lane_dot_folded(bar, &x, &y, 1000),
            reference::lane_dot(bar, &x, &y)
        );
    }

    #[test]
    fn dispatch_bit_identical_at_exactly_31_and_32_bit_moduli() {
        // The wide-modulus fallback decision now lives in the dispatch
        // shims (hoisted out of the loop preludes): pin bit-identity on
        // both sides of that boundary — the widest lane-legal modulus
        // (exactly 31 bits, deferred/SIMD path) and the narrowest wide
        // modulus (exactly 32 bits, reference fallback path).
        let m31 = (1u64 << 31) - 1; // 31 bits: deferred_ok
        let m32 = (1u64 << 31) + 11; // 32 bits: reference fallback
        assert!(Barrett::new(m31).deferred_ok());
        assert!(!Barrett::new(m32).deferred_ok());
        let mut rng = Rng::new(77);
        for m in [m31, m32] {
            let bar = Barrett::new(m);
            for n in [0usize, 1, 3, 4, 7, 33, 257] {
                let x = random_lane(&mut rng, m, n);
                let y = random_lane(&mut rng, m, n);
                let mults = random_lane(&mut rng, m, n);
                let mut acc = random_lane(&mut rng, m, n);
                let mut acc_ref = acc.clone();
                lane_fma(bar, &mut acc, &x, &y);
                reference::lane_fma(bar, &mut acc_ref, &x, &y);
                assert_eq!(acc, acc_ref, "lane_fma m={m} n={n}");
                assert_eq!(
                    lane_dot(bar, &x, &y),
                    reference::lane_dot(bar, &x, &y),
                    "lane_dot m={m} n={n}"
                );
                assert_eq!(
                    lane_dot_scaled(bar, &x, &y, &mults),
                    reference::lane_dot_scaled(bar, &x, &y, &mults),
                    "lane_dot_scaled m={m} n={n}"
                );
            }
        }
    }

    #[test]
    fn wide_modulus_falls_back_to_reference() {
        // A 32-bit modulus (legal for scalar Barrett, outside the lane
        // invariant) must still compute correctly via the reference
        // fallback paths.
        let m = (1u64 << 32) - 5;
        let bar = Barrett::new(m);
        assert!(!bar.deferred_ok());
        let mut rng = Rng::new(23);
        let x = random_lane(&mut rng, m, 33);
        let y = random_lane(&mut rng, m, 33);
        let mults = random_lane(&mut rng, m, 33);
        assert_eq!(lane_dot(bar, &x, &y), reference::lane_dot(bar, &x, &y));
        assert_eq!(
            lane_dot_scaled(bar, &x, &y, &mults),
            reference::lane_dot_scaled(bar, &x, &y, &mults)
        );
        let mut acc = random_lane(&mut rng, m, 33);
        let mut acc_ref = acc.clone();
        lane_fma(bar, &mut acc, &x, &y);
        reference::lane_fma(bar, &mut acc_ref, &x, &y);
        assert_eq!(acc, acc_ref);
    }
}
