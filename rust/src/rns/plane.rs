//! Planar (structure-of-arrays) residue storage and the batched per-channel
//! kernels that run on it.
//!
//! A [`ResiduePlane`] holds a batch of `n` residue vectors as `k` contiguous
//! `u64` lanes, one per modulus: `lanes[c * n + j]` is channel `c` of element
//! `j`. This is the software mirror of the paper's hardware layout (one
//! modular pipeline per channel, §VI-B): each lane is walked by a tight,
//! allocation-free, auto-vectorizable loop instead of the pointer-chasing
//! per-element [`ResidueVec`] path, and it is the layout the AOT kernels
//! already use (`int64[k, n]` channel-major tensors).
//!
//! The plane is pure residue data. Exponent and interval bookkeeping for a
//! batch of HRFNA values lives in [`crate::hybrid::batch::HrfnaBatch`],
//! which drives these kernels.

use super::barrett::Barrett;
use super::residue::ResidueVec;
use thiserror::Error;

/// Errors for fallible plane constructors.
#[derive(Clone, Debug, Error, PartialEq, Eq)]
pub enum PlaneError {
    /// Lane buffer length does not match `k * n`.
    #[error("lane data length {got} != k*n = {want}")]
    LaneLen { got: usize, want: usize },
    /// Two planes with different shapes were combined.
    #[error("plane shape mismatch: {0}x{1} vs {2}x{3}")]
    Shape(usize, usize, usize, usize),
}

/// A batch of residue vectors in channel-major planar layout.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ResiduePlane {
    k: usize,
    n: usize,
    lanes: Vec<u64>,
}

impl ResiduePlane {
    /// All-zero plane (a batch of `n` zero values over `k` channels).
    pub fn zero(k: usize, n: usize) -> ResiduePlane {
        ResiduePlane {
            k,
            n,
            lanes: vec![0; k * n],
        }
    }

    /// Wrap an existing channel-major lane buffer.
    pub fn from_lanes(k: usize, n: usize, lanes: Vec<u64>) -> Result<ResiduePlane, PlaneError> {
        if lanes.len() != k * n {
            return Err(PlaneError::LaneLen {
                got: lanes.len(),
                want: k * n,
            });
        }
        Ok(ResiduePlane { k, n, lanes })
    }

    /// Encode a batch of signed integers (M-complement per channel), with
    /// contiguous per-channel writes — the planar form of the block-encode
    /// inner loop (`coordinator::hybrid_exec::encode_block`).
    pub fn encode_signed(staged: &[i64], moduli: &[u64], bars: &[Barrett]) -> ResiduePlane {
        debug_assert_eq!(moduli.len(), bars.len());
        let k = moduli.len();
        let n = staged.len();
        let mut lanes = vec![0u64; k * n];
        for c in 0..k {
            let bar = bars[c];
            let m = moduli[c];
            let row = &mut lanes[c * n..(c + 1) * n];
            for (out, &s) in row.iter_mut().zip(staged) {
                let r = bar.reduce(s.unsigned_abs());
                *out = if s < 0 && r != 0 { m - r } else { r };
            }
        }
        ResiduePlane { k, n, lanes }
    }

    /// The [`ResiduePlane::encode_signed`] lane loop writing straight into
    /// an `i64` channel-major buffer — the PJRT tensor form. One pass, no
    /// intermediate plane (the serving hot path's block encode).
    pub fn encode_signed_i64(staged: &[i64], moduli: &[u64], bars: &[Barrett]) -> Vec<i64> {
        debug_assert_eq!(moduli.len(), bars.len());
        let k = moduli.len();
        let n = staged.len();
        let mut lanes = vec![0i64; k * n];
        for c in 0..k {
            let bar = bars[c];
            let m = moduli[c];
            let row = &mut lanes[c * n..(c + 1) * n];
            for (out, &s) in row.iter_mut().zip(staged) {
                let r = bar.reduce(s.unsigned_abs());
                *out = if s < 0 && r != 0 { (m - r) as i64 } else { r as i64 };
            }
        }
        lanes
    }

    /// Number of channels.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of elements.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// One channel's contiguous lane.
    #[inline]
    pub fn lane(&self, c: usize) -> &[u64] {
        &self.lanes[c * self.n..(c + 1) * self.n]
    }

    /// Mutable lane access.
    #[inline]
    pub fn lane_mut(&mut self, c: usize) -> &mut [u64] {
        &mut self.lanes[c * self.n..(c + 1) * self.n]
    }

    /// The raw channel-major buffer.
    #[inline]
    pub fn lanes(&self) -> &[u64] {
        &self.lanes
    }

    /// Gather element `j` across channels into a [`ResidueVec`].
    pub fn get(&self, j: usize) -> ResidueVec {
        ResidueVec {
            r: (0..self.k).map(|c| self.lanes[c * self.n + j]).collect(),
        }
    }

    /// Scatter a [`ResidueVec`] into element `j`.
    pub fn set(&mut self, j: usize, r: &ResidueVec) {
        debug_assert_eq!(r.k(), self.k);
        for (c, &v) in r.r.iter().enumerate() {
            self.lanes[c * self.n + j] = v;
        }
    }

    /// Elementwise modular multiplication (lane-parallel Definition 2).
    pub fn mul(&self, other: &ResiduePlane, bars: &[Barrett]) -> ResiduePlane {
        debug_assert_eq!((self.k, self.n), (other.k, other.n));
        let mut out = ResiduePlane::zero(self.k, self.n);
        for c in 0..self.k {
            lane_mul(bars[c], self.lane(c), other.lane(c), out.lane_mut(c));
        }
        out
    }

    /// Elementwise modular addition.
    pub fn add(&self, other: &ResiduePlane, bars: &[Barrett]) -> ResiduePlane {
        debug_assert_eq!((self.k, self.n), (other.k, other.n));
        let mut out = ResiduePlane::zero(self.k, self.n);
        for c in 0..self.k {
            lane_add(bars[c], self.lane(c), other.lane(c), out.lane_mut(c));
        }
        out
    }

    /// Elementwise M-complement negation.
    pub fn neg(&self, moduli: &[u64]) -> ResiduePlane {
        let mut out = ResiduePlane::zero(self.k, self.n);
        for c in 0..self.k {
            lane_neg(moduli[c], self.lane(c), out.lane_mut(c));
        }
        out
    }

    /// In-place fused multiply-accumulate: `self[c][j] += x[c][j] * y[c][j]`
    /// per channel — the planar hot loop of Algorithm 1.
    pub fn fma_assign(&mut self, x: &ResiduePlane, y: &ResiduePlane, bars: &[Barrett]) {
        debug_assert_eq!((self.k, self.n), (x.k, x.n));
        debug_assert_eq!((self.k, self.n), (y.k, y.n));
        let n = self.n;
        for c in 0..self.k {
            let bar = bars[c];
            let acc = &mut self.lanes[c * n..(c + 1) * n];
            let xs = &x.lanes[c * n..(c + 1) * n];
            let ys = &y.lanes[c * n..(c + 1) * n];
            for j in 0..n {
                acc[j] = bar.add(acc[j], bar.mul(xs[j], ys[j]));
            }
        }
    }

    /// True per element iff any channel residue is nonzero (i.e. the
    /// represented integer is nonzero). One contiguous pass per lane.
    pub fn nonzero_mask(&self) -> Vec<bool> {
        let mut nz = vec![false; self.n];
        for c in 0..self.k {
            for (flag, &v) in nz.iter_mut().zip(self.lane(c)) {
                *flag |= v != 0;
            }
        }
        nz
    }
}

/// `out[j] = (x[j] * y[j]) mod m` over one lane.
#[inline]
pub fn lane_mul(bar: Barrett, x: &[u64], y: &[u64], out: &mut [u64]) {
    for ((o, &a), &b) in out.iter_mut().zip(x).zip(y) {
        *o = bar.mul(a, b);
    }
}

/// `out[j] = (x[j] + y[j]) mod m` over one lane.
#[inline]
pub fn lane_add(bar: Barrett, x: &[u64], y: &[u64], out: &mut [u64]) {
    for ((o, &a), &b) in out.iter_mut().zip(x).zip(y) {
        *o = bar.add(a, b);
    }
}

/// `out[j] = (m - x[j]) mod m` over one lane (M-complement negation).
#[inline]
pub fn lane_neg(m: u64, x: &[u64], out: &mut [u64]) {
    for (o, &a) in out.iter_mut().zip(x) {
        *o = if a == 0 { 0 } else { m - a };
    }
}

/// `out[j] = (x[j] * mult) mod m` over one lane (residue-domain scaling,
/// e.g. by a precomputed `2^Δ mod m`).
#[inline]
pub fn lane_scale(bar: Barrett, x: &[u64], mult: u64, out: &mut [u64]) {
    for (o, &a) in out.iter_mut().zip(x) {
        *o = bar.mul(a, mult);
    }
}

/// Modular dot product over one lane: `Σ_j x[j]·y[j] mod m`.
#[inline]
pub fn lane_dot(bar: Barrett, x: &[u64], y: &[u64]) -> u64 {
    let mut acc = 0u64;
    for (&a, &b) in x.iter().zip(y) {
        acc = bar.add(acc, bar.mul(a, b));
    }
    acc
}

/// Modular dot product with a per-element scale factor:
/// `Σ_j x[j]·y[j]·mults[j] mod m` — the exponent-aligned accumulation of
/// Algorithm 1 with `mults[j] = 2^{Δ_j} mod m`.
#[inline]
pub fn lane_dot_scaled(bar: Barrett, x: &[u64], y: &[u64], mults: &[u64]) -> u64 {
    let mut acc = 0u64;
    for ((&a, &b), &s) in x.iter().zip(y).zip(mults) {
        acc = bar.add(acc, bar.mul(bar.mul(a, b), s));
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::barrett::barrett_set;
    use crate::rns::moduli::DEFAULT_MODULI;
    use crate::util::proptest::check_with;
    use crate::util::prng::Rng;

    fn bars() -> Vec<Barrett> {
        barrett_set(&DEFAULT_MODULI)
    }

    fn random_plane(rng: &mut Rng, n: usize) -> ResiduePlane {
        let k = DEFAULT_MODULI.len();
        let mut p = ResiduePlane::zero(k, n);
        for c in 0..k {
            let m = DEFAULT_MODULI[c];
            for v in p.lane_mut(c) {
                *v = rng.below(m);
            }
        }
        p
    }

    #[test]
    fn from_lanes_validates_shape() {
        assert!(ResiduePlane::from_lanes(2, 3, vec![0; 6]).is_ok());
        assert_eq!(
            ResiduePlane::from_lanes(2, 3, vec![0; 5]),
            Err(PlaneError::LaneLen { got: 5, want: 6 })
        );
    }

    #[test]
    fn get_set_roundtrip() {
        let mut p = ResiduePlane::zero(8, 4);
        let r = ResidueVec::encode_u64(123_456_789, &DEFAULT_MODULI);
        p.set(2, &r);
        assert_eq!(p.get(2), r);
        assert!(p.get(0).is_zero());
        let nz = p.nonzero_mask();
        assert_eq!(nz, vec![false, false, true, false]);
    }

    #[test]
    fn encode_signed_matches_scalar_encode() {
        let b = bars();
        let staged: Vec<i64> = vec![0, 1, -1, 42, -65521, 65524, i64::MAX, i64::MIN + 1];
        let p = ResiduePlane::encode_signed(&staged, &DEFAULT_MODULI, &b);
        for (j, &s) in staged.iter().enumerate() {
            let want: Vec<u64> = DEFAULT_MODULI
                .iter()
                .map(|&m| {
                    let r = s.unsigned_abs() % m;
                    if s < 0 && r != 0 {
                        m - r
                    } else {
                        r
                    }
                })
                .collect();
            assert_eq!(p.get(j).r, want, "j={j} s={s}");
        }
    }

    #[test]
    fn encode_signed_i64_matches_plane_encode() {
        let b = bars();
        let staged: Vec<i64> = vec![0, 7, -7, 65520, -65522, 1 << 40, -(1 << 40)];
        let plane = ResiduePlane::encode_signed(&staged, &DEFAULT_MODULI, &b);
        let lanes = ResiduePlane::encode_signed_i64(&staged, &DEFAULT_MODULI, &b);
        assert_eq!(lanes.len(), plane.lanes().len());
        for (a, &u) in lanes.iter().zip(plane.lanes()) {
            assert_eq!(*a, u as i64);
        }
    }

    #[test]
    fn prop_plane_ops_match_residuevec_ops() {
        let b = bars();
        check_with("plane-vs-residuevec", 64, |rng| {
            let n = 1 + rng.below(33) as usize;
            let x = random_plane(rng, n);
            let y = random_plane(rng, n);
            let mul = x.mul(&y, &b);
            let add = x.add(&y, &b);
            let neg = x.neg(&DEFAULT_MODULI);
            let mut fma = x.clone();
            fma.fma_assign(&x, &y, &b);
            for j in 0..n {
                let xv = x.get(j);
                let yv = y.get(j);
                crate::prop_assert!(mul.get(j) == xv.mul(&yv, &b), "mul j={j}");
                crate::prop_assert!(add.get(j) == xv.add(&yv, &b), "add j={j}");
                let mut mac = xv.clone();
                mac.mac_assign(&xv, &yv, &b);
                crate::prop_assert!(fma.get(j) == mac, "fma j={j}");
                let nv = neg.get(j);
                crate::prop_assert!(
                    xv.add(&nv, &b).is_zero(),
                    "neg is not the additive inverse j={j}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn lane_dot_matches_sequential_mac() {
        let b = bars();
        let mut rng = Rng::new(9);
        let n = 257;
        let x = random_plane(&mut rng, n);
        let y = random_plane(&mut rng, n);
        for c in 0..x.k() {
            let bar = b[c];
            let mut want = 0u64;
            for j in 0..n {
                want = bar.add(want, bar.mul(x.lane(c)[j], y.lane(c)[j]));
            }
            assert_eq!(lane_dot(bar, x.lane(c), y.lane(c)), want, "c={c}");
            // Scaled variant with all-ones multipliers degenerates to dot.
            let ones = vec![1u64; n];
            assert_eq!(
                lane_dot_scaled(bar, x.lane(c), y.lane(c), &ones),
                want,
                "scaled c={c}"
            );
        }
    }

    #[test]
    fn lane_scale_matches_pointwise() {
        let b = bars();
        let mut rng = Rng::new(11);
        let x = random_plane(&mut rng, 17);
        for c in 0..x.k() {
            let mult = rng.below(DEFAULT_MODULI[c]);
            let mut out = vec![0u64; 17];
            lane_scale(b[c], x.lane(c), mult, &mut out);
            for j in 0..17 {
                assert_eq!(out[j], b[c].mul(x.lane(c)[j], mult));
            }
        }
    }
}
