//! Chinese Remainder Theorem reconstruction (paper §III-A semantics, §VI-E
//! normalization engine) and mixed-radix conversion (the reconstruction-free
//! comparison alternative discussed in §II-D).
//!
//! `CrtContext` precomputes, per channel, `M_i = M / m_i` and
//! `inv_i = M_i^{-1} mod m_i`, so reconstruction is
//! `N = Σ r_i · inv_i · M_i  mod M` — exactly the structure a pipelined
//! CRT engine evaluates.

use super::barrett::{barrett_set, Barrett, InvPow2, ShoupMul};
use super::moduli::{composite_modulus, is_pairwise_coprime, pow_mod};
use super::residue::ResidueVec;
use crate::bigint::BigUint;

/// Extended gcd on i128: returns (g, x, y) with a·x + b·y = g.
fn egcd(a: i128, b: i128) -> (i128, i128, i128) {
    if b == 0 {
        (a, 1, 0)
    } else {
        let (g, x, y) = egcd(b, a % b);
        (g, y, x - (a / b) * y)
    }
}

/// Modular inverse of `a` mod `m` (panics if not coprime).
pub fn inv_mod(a: u64, m: u64) -> u64 {
    let (g, x, _) = egcd(a as i128, m as i128);
    assert!(g == 1, "inv_mod: {a} not invertible mod {m}");
    (x.rem_euclid(m as i128)) as u64
}

/// Precomputed CRT reconstruction context for a modulus set.
#[derive(Clone, Debug)]
pub struct CrtContext {
    pub moduli: Vec<u64>,
    pub barrett: Vec<Barrett>,
    /// Composite modulus M = Π m_i.
    pub big_m: BigUint,
    /// Precombined per-channel term basis: T_i = (inv_i · M_i) mod M.
    /// Reconstruction is then N = Σ r_i·T_i mod M.
    term: Vec<BigUint>,
    /// Mixed-radix factors m_j^{-1} mod m_i for j < i (lower-triangular).
    mrc_inv: Vec<Vec<u64>>,
    /// §Perf fast path: `term[i]` as fixed little-endian limbs, all padded
    /// to a common width (`fixed_limbs`), so reconstruction runs over
    /// stack arrays with no heap allocation.
    term_limbs: Vec<[u64; FIXED_LIMBS]>,
    /// M as fixed limbs.
    m_limbs: [u64; FIXED_LIMBS],
    /// ⌊M/2⌋ — the M-complement sign boundary, hoisted out of every
    /// signed reconstruction (it used to be recomputed per call).
    half: BigUint,
    /// ⌊M/2⌋ as fixed limbs for the stack-array sign test.
    half_limbs: [u64; FIXED_LIMBS],
    /// True when k and bit sizes fit the fixed-width fast path.
    fixed_ok: bool,
    /// Per-channel Shoup constants for `2^{64·t} mod m_i`, `t <
    /// FIXED_LIMBS` — the limb-fold basis that reduces a fixed-width
    /// integer mod `m_i` with multiplies only (no division), used by the
    /// normalization engine's batched rescale.
    limb_base: Vec<[ShoupMul; FIXED_LIMBS]>,
    /// Per-channel `2^{-s} mod m_i` Shoup tables (odd modulus sets only):
    /// the residue-domain re-encode constants of [`CrtContext::rescale_batch`].
    inv_pow2: Option<Vec<InvPow2>>,
}

/// Depth of the per-channel `2^{-s} mod m_i` tables: shifts from the
/// normalization engine are bounded by the fixed-width magnitude
/// (`FIXED_LIMBS·64` bits); anything deeper takes the pow-ladder
/// fallback inside [`InvPow2::mul_inv_pow2`].
const INV_POW2_DEPTH: usize = FIXED_LIMBS * 64 + 64;

/// Outcome of one element of a batched rescale
/// ([`CrtContext::rescale_batch`]): the post-event sign and the lossy-f64
/// magnitudes before/after (same truncation as [`BigUint::to_f64`]) —
/// what the normalization engine needs to reseed intervals and verify
/// Lemma 1/2 budgets without any further reconstruction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rescaled {
    /// Sign of the rescaled value (false once it rounds to zero).
    pub neg: bool,
    /// `|N|` before the event.
    pub mag_before: f64,
    /// `|round(N / 2^s)|` after the event.
    pub mag_after: f64,
}

/// Fixed reconstruction width: 5×64 = 320 bits covers M up to ~2^288 plus
/// the Σ rᵢ·Tᵢ headroom (k ≤ 16 channels of 32-bit moduli).
const FIXED_LIMBS: usize = 5;

#[inline]
fn to_fixed(b: &BigUint) -> Option<[u64; FIXED_LIMBS]> {
    if b.limbs.len() > FIXED_LIMBS {
        return None;
    }
    let mut out = [0u64; FIXED_LIMBS];
    out[..b.limbs.len()].copy_from_slice(&b.limbs);
    Some(out)
}

/// acc += t * r (fixed width, carry-propagating). Returns overflow.
#[inline]
fn fixed_mul_acc(acc: &mut [u64; FIXED_LIMBS], t: &[u64; FIXED_LIMBS], r: u64) -> bool {
    let mut carry: u128 = 0;
    for (a, &tl) in acc.iter_mut().zip(t) {
        let v = *a as u128 + (tl as u128) * (r as u128) + carry;
        *a = v as u64;
        carry = v >> 64;
    }
    carry != 0
}

/// Compare fixed-width values.
#[inline]
fn fixed_cmp(a: &[u64; FIXED_LIMBS], b: &[u64; FIXED_LIMBS]) -> std::cmp::Ordering {
    for (al, bl) in a.iter().zip(b).rev() {
        match al.cmp(bl) {
            std::cmp::Ordering::Equal => continue,
            o => return o,
        }
    }
    std::cmp::Ordering::Equal
}

/// True iff the fixed-width value is zero.
#[inline]
fn fixed_is_zero(a: &[u64; FIXED_LIMBS]) -> bool {
    a.iter().all(|&l| l == 0)
}

/// Bit `i` of a fixed-width value (false beyond the top).
#[inline]
fn fixed_bit(a: &[u64; FIXED_LIMBS], i: u32) -> bool {
    let limb = (i / 64) as usize;
    limb < FIXED_LIMBS && (a[limb] >> (i % 64)) & 1 == 1
}

/// `a >> s` (fixed width; zero once the shift clears every limb).
fn fixed_shr(a: &[u64; FIXED_LIMBS], s: u32) -> [u64; FIXED_LIMBS] {
    let mut out = [0u64; FIXED_LIMBS];
    let limb_s = (s / 64) as usize;
    if limb_s >= FIXED_LIMBS {
        return out;
    }
    let bit_s = s % 64;
    for i in 0..FIXED_LIMBS - limb_s {
        let lo = a[i + limb_s] >> bit_s;
        let hi = if bit_s > 0 && i + limb_s + 1 < FIXED_LIMBS {
            a[i + limb_s + 1] << (64 - bit_s)
        } else {
            0
        };
        out[i] = lo | hi;
    }
    out
}

/// `a += 1` (fixed width; the caller guarantees headroom).
#[inline]
fn fixed_add_one(a: &mut [u64; FIXED_LIMBS]) {
    for l in a.iter_mut() {
        let (v, carry) = l.overflowing_add(1);
        *l = v;
        if !carry {
            return;
        }
    }
}

/// `a mod 2^s` (the low `s` bits of a fixed-width value).
fn fixed_low_bits(a: &[u64; FIXED_LIMBS], s: u32) -> [u64; FIXED_LIMBS] {
    let mut out = [0u64; FIXED_LIMBS];
    let full = ((s / 64) as usize).min(FIXED_LIMBS);
    out[..full].copy_from_slice(&a[..full]);
    let rem = s % 64;
    if full < FIXED_LIMBS && rem > 0 {
        out[full] = a[full] & ((1u64 << rem) - 1);
    }
    out
}

/// `2^s` as a fixed-width value (`s < FIXED_LIMBS·64`).
#[inline]
fn fixed_pow2(s: u32) -> [u64; FIXED_LIMBS] {
    debug_assert!((s as usize) < FIXED_LIMBS * 64);
    let mut out = [0u64; FIXED_LIMBS];
    out[(s / 64) as usize] = 1u64 << (s % 64);
    out
}

/// Lossy conversion of a fixed-width value to f64: strip the zero
/// padding, then the **shared** [`crate::bigint::limbs_to_f64`] — one
/// definition for BigUint and fixed-width paths, so the normalization
/// engine's interval reseeds are bit-identical to the scalar decode by
/// construction, not by parallel maintenance.
fn fixed_to_f64(a: &[u64; FIXED_LIMBS]) -> f64 {
    let n = FIXED_LIMBS - a.iter().rev().take_while(|&&l| l == 0).count();
    crate::bigint::limbs_to_f64(&a[..n])
}

/// a -= b (fixed width; caller guarantees a >= b).
#[inline]
fn fixed_sub(a: &mut [u64; FIXED_LIMBS], b: &[u64; FIXED_LIMBS]) {
    let mut borrow = 0u64;
    for (al, &bl) in a.iter_mut().zip(b) {
        let (d1, b1) = al.overflowing_sub(bl);
        let (d2, b2) = d1.overflowing_sub(borrow);
        *al = d2;
        borrow = (b1 as u64) + (b2 as u64);
    }
    debug_assert_eq!(borrow, 0);
}

impl CrtContext {
    /// Build a context; validates pairwise coprimality.
    pub fn new(moduli: &[u64]) -> CrtContext {
        assert!(!moduli.is_empty());
        assert!(
            is_pairwise_coprime(moduli),
            "moduli must be pairwise coprime"
        );
        let big_m = composite_modulus(moduli);
        let m_over: Vec<BigUint> = moduli
            .iter()
            .map(|&mi| big_m.div_rem_u64(mi).0)
            .collect();
        let inv: Vec<u64> = moduli
            .iter()
            .zip(&m_over)
            .map(|(&mi, mo)| inv_mod(mo.rem_u64(mi), mi))
            .collect();
        let term: Vec<BigUint> = m_over
            .iter()
            .zip(&inv)
            .map(|(mo, &iv)| mo.mul_u64(iv).rem_big(&big_m))
            .collect();
        let mrc_inv = (0..moduli.len())
            .map(|i| {
                (0..i)
                    .map(|j| inv_mod(moduli[j] % moduli[i], moduli[i]))
                    .collect()
            })
            .collect();
        // §Perf fixed-width tables: valid when M (and the Σ rᵢTᵢ headroom
        // of k · max(m) beyond it) fits FIXED_LIMBS.
        let headroom_bits =
            big_m.bit_length() + 64 + (moduli.len() as f64).log2().ceil() as u32;
        let fixed_ok = headroom_bits < (FIXED_LIMBS as u32) * 64;
        let term_limbs = term
            .iter()
            .map(|t| to_fixed(t).unwrap_or([0; FIXED_LIMBS]))
            .collect();
        let m_limbs = to_fixed(&big_m).unwrap_or([0; FIXED_LIMBS]);
        let half = big_m.shr(1);
        let half_limbs = to_fixed(&half).unwrap_or([0; FIXED_LIMBS]);
        let barrett = barrett_set(moduli);
        // The rescale tables serve only the fixed-width fast path of
        // `rescale_batch`; exotic sets (outside the fixed window, or with
        // an even modulus where 2 has no inverse) take the BigUint
        // mirror, so don't pay k×(FIXED_LIMBS + INV_POW2_DEPTH) Shoup
        // precomputations for tables no code path can reach. Construction
        // is eager for the reachable case: contexts are setup-time
        // configuration and the whole table build is ~0.1 ms at k = 8.
        let rescale_fast_ok = fixed_ok && moduli.iter().all(|&m| m % 2 == 1);
        let (limb_base, inv_pow2) = if rescale_fast_ok {
            let limb_base = moduli
                .iter()
                .zip(&barrett)
                .map(|(&m, bar)| {
                    let base64 = pow_mod(2, 64, m);
                    let mut v = 1 % m;
                    let mut row = [ShoupMul::new(bar, 0); FIXED_LIMBS];
                    for slot in row.iter_mut() {
                        *slot = ShoupMul::new(bar, v);
                        v = bar.mul(v, base64);
                    }
                    row
                })
                .collect();
            let inv_pow2 = barrett
                .iter()
                .map(|bar| bar.inv_pow2(INV_POW2_DEPTH))
                .collect::<Option<Vec<_>>>();
            (limb_base, inv_pow2)
        } else {
            (Vec::new(), None)
        };
        CrtContext {
            barrett,
            moduli: moduli.to_vec(),
            big_m,
            term,
            mrc_inv,
            term_limbs,
            m_limbs,
            half,
            half_limbs,
            fixed_ok,
            limb_base,
            inv_pow2,
        }
    }

    /// Number of channels.
    pub fn k(&self) -> usize {
        self.moduli.len()
    }

    /// The fixed-width accumulation core: `acc = Σ read(i)·Tᵢ mod M` over
    /// a stack array. `read(i)` supplies channel `i`'s residue, so batch
    /// callers can stream residues straight out of channel-major lanes
    /// with no per-output `ResidueVec` gather.
    #[inline]
    fn fixed_accumulate(&self, mut read: impl FnMut(usize) -> u64) -> [u64; FIXED_LIMBS] {
        let mut acc = [0u64; FIXED_LIMBS];
        for (i, term) in self.term_limbs.iter().enumerate() {
            let ri = read(i);
            if ri != 0 {
                let overflow = fixed_mul_acc(&mut acc, term, ri);
                debug_assert!(!overflow, "fixed-width CRT overflow");
            }
        }
        self.fixed_reduce_mod_m(&mut acc);
        acc
    }

    /// Reduce a fixed-width `acc < k·max(m)·M` (≤ M << ~20 bits) mod M by
    /// conditional subtractions of shifted M — no heap allocation, no
    /// general division.
    fn fixed_reduce_mod_m(&self, acc: &mut [u64; FIXED_LIMBS]) {
        // Find the highest shift where (M << s) could still be ≤ acc.
        let m_bits = self.big_m.bit_length();
        let acc_bits = {
            let mut bits = 0;
            for (i, &limb) in acc.iter().enumerate().rev() {
                if limb != 0 {
                    bits = i as u32 * 64 + (64 - limb.leading_zeros());
                    break;
                }
            }
            bits
        };
        if acc_bits >= m_bits {
            let mut s = acc_bits - m_bits;
            loop {
                // shifted = M << s (fixed width; s ≤ ~24 so it fits).
                let mut shifted = [0u64; FIXED_LIMBS];
                let limb_s = (s / 64) as usize;
                let bit_s = s % 64;
                for i in 0..FIXED_LIMBS - limb_s {
                    let lo = self.m_limbs[i] << bit_s;
                    let hi = if bit_s > 0 && i > 0 {
                        self.m_limbs[i - 1] >> (64 - bit_s)
                    } else {
                        0
                    };
                    shifted[i + limb_s] = lo | hi;
                }
                while fixed_cmp(acc, &shifted) != std::cmp::Ordering::Less {
                    fixed_sub(acc, &shifted);
                }
                if s == 0 {
                    break;
                }
                s -= 1;
            }
        }
    }

    /// Apply the M-complement sign convention to a fixed-width `N ∈ [0, M)`
    /// using the precomputed ⌊M/2⌋ limbs (no BigUint compare, no per-call
    /// shift).
    #[inline]
    fn signed_from_fixed(&self, acc: [u64; FIXED_LIMBS]) -> (bool, BigUint) {
        if fixed_cmp(&acc, &self.half_limbs) != std::cmp::Ordering::Less {
            let mut mag = self.m_limbs;
            fixed_sub(&mut mag, &acc);
            (true, BigUint::from_limbs(mag.to_vec()))
        } else {
            (false, BigUint::from_limbs(acc.to_vec()))
        }
    }

    /// Sign convention on a BigUint `N ∈ [0, M)` (slow-path mirror of
    /// [`CrtContext::signed_from_fixed`]).
    #[inline]
    fn signed_from_big(&self, n: BigUint) -> (bool, BigUint) {
        if n >= self.half {
            (true, self.big_m.sub(&n))
        } else {
            (false, n)
        }
    }

    /// CRT reconstruction: the unique `N ∈ [0, M)` with `N ≡ r_i (mod m_i)`.
    ///
    /// §Perf: the default path accumulates `Σ rᵢ·Tᵢ` in a fixed-width
    /// stack array and reduces mod M by (at most k) conditional
    /// subtractions of shifted M — no heap allocation, no general
    /// division. Falls back to BigUint for exotic modulus sets.
    pub fn reconstruct(&self, r: &ResidueVec) -> BigUint {
        assert_eq!(r.k(), self.k());
        if !self.fixed_ok {
            return self.reconstruct_slow(r);
        }
        let acc = self.fixed_accumulate(|i| r.r[i]);
        BigUint::from_limbs(acc.to_vec())
    }

    /// Allocation-heavy fallback reconstruction (arbitrary modulus sets).
    fn reconstruct_slow(&self, r: &ResidueVec) -> BigUint {
        let mut acc = BigUint::zero();
        for (i, &ri) in r.r.iter().enumerate() {
            if ri != 0 {
                acc = acc.add(&self.term[i].mul_u64(ri));
            }
        }
        acc.rem_big(&self.big_m)
    }

    /// Signed reconstruction under the symmetric convention: values in
    /// `[0, M/2)` are non-negative, `[M/2, M)` map to `N - M` (standard RNS
    /// sign handling; HRFNA encodes negatives this way).
    pub fn reconstruct_signed(&self, r: &ResidueVec) -> (bool, BigUint) {
        assert_eq!(r.k(), self.k());
        if !self.fixed_ok {
            let n = self.reconstruct_slow(r);
            return self.signed_from_big(n);
        }
        self.signed_from_fixed(self.fixed_accumulate(|i| r.r[i]))
    }

    /// Batched CRT over channel-major lanes (`lanes[c*n + j]` is channel
    /// `c` of output `j` — a [`super::plane::ResiduePlane`] buffer or any
    /// `k × n` residue block). The per-modulus `(invᵢ·Mᵢ) mod M` term
    /// table, the fixed-limb scratch discipline and the reduction state
    /// are hoisted out of the per-output loop — no per-output
    /// `ResidueVec`, no per-output sign-boundary recompute.
    pub fn reconstruct_batch(&self, lanes: &[u64], n: usize) -> Vec<BigUint> {
        assert_eq!(lanes.len(), self.k() * n, "lanes must be k×n channel-major");
        if self.fixed_ok {
            (0..n)
                .map(|j| BigUint::from_limbs(self.fixed_accumulate(|c| lanes[c * n + j]).to_vec()))
                .collect()
        } else {
            (0..n)
                .map(|j| self.reconstruct_slow(&self.gather(lanes, n, j)))
                .collect()
        }
    }

    /// Batched signed reconstruction over channel-major lanes (see
    /// [`CrtContext::reconstruct_batch`]); one `(negative, magnitude)`
    /// pair per output.
    pub fn reconstruct_signed_batch(&self, lanes: &[u64], n: usize) -> Vec<(bool, BigUint)> {
        assert_eq!(lanes.len(), self.k() * n, "lanes must be k×n channel-major");
        self.reconstruct_signed_batch_with(n, |c, j| lanes[c * n + j])
    }

    /// Batched signed reconstruction with a caller-supplied residue read
    /// `read(channel, elem)` — the zero-copy form for residue blocks that
    /// are not `u64` lanes (e.g. the coordinator's `i64` PJRT tensors).
    pub fn reconstruct_signed_batch_with<F>(&self, n: usize, mut read: F) -> Vec<(bool, BigUint)>
    where
        F: FnMut(usize, usize) -> u64,
    {
        if self.fixed_ok {
            (0..n)
                .map(|j| self.signed_from_fixed(self.fixed_accumulate(|c| read(c, j))))
                .collect()
        } else {
            (0..n)
                .map(|j| {
                    let rv = ResidueVec {
                        r: (0..self.k()).map(|c| read(c, j)).collect(),
                    };
                    self.signed_from_big(self.reconstruct_slow(&rv))
                })
                .collect()
        }
    }

    /// Gather output `j` of a channel-major lane block (slow path only).
    fn gather(&self, lanes: &[u64], n: usize, j: usize) -> ResidueVec {
        ResidueVec {
            r: (0..self.k()).map(|c| lanes[c * n + j]).collect(),
        }
    }

    /// Batched Definition-4 rescale over channel-major lanes: element `j`
    /// — the signed M-complement value `N_j` — becomes
    /// `round(N_j / 2^{shifts[j]})` (round-half-away-from-zero, so the
    /// Lemma 1 half-unit bound holds), re-encoded **without leaving the
    /// residue domain**: one fixed-width reconstruction yields the
    /// rounding offset `d = |N'_j·2^s − N_j| < 2^s` (the distance to the
    /// shifted grid), `d` folds to `d mod m_i` through the precomputed
    /// `2^{64t} mod m_i` limb basis, and the new residues are
    /// `(r_i ± d_i) · 2^{-s} mod m_i` via the precomputed inverse-power
    /// Shoup constants — no BigUint re-encode, no per-element allocation.
    ///
    /// `shifts[j] == 0` leaves element `j` untouched. Falls back to the
    /// scalar BigUint mirror for modulus sets outside the fixed-width
    /// window or containing an even modulus (2 is not invertible there).
    pub fn rescale_batch(&self, lanes: &mut [u64], n: usize, shifts: &[u32]) -> Vec<Rescaled> {
        let k = self.k();
        assert_eq!(lanes.len(), k * n, "lanes must be k×n channel-major");
        assert_eq!(shifts.len(), n, "one shift per element");
        let Some(inv) = self.inv_pow2.as_ref().filter(|_| self.fixed_ok) else {
            return self.rescale_batch_slow(lanes, n, shifts);
        };
        let mut out = Vec::with_capacity(n);
        for (j, &s) in shifts.iter().enumerate() {
            let acc = self.fixed_accumulate(|c| lanes[c * n + j]);
            let neg = fixed_cmp(&acc, &self.half_limbs) != std::cmp::Ordering::Less;
            let mag = if neg {
                let mut m = self.m_limbs;
                fixed_sub(&mut m, &acc);
                m
            } else {
                acc
            };
            let mag_before = fixed_to_f64(&mag);
            if s == 0 {
                out.push(Rescaled {
                    neg: neg && !fixed_is_zero(&mag),
                    mag_before,
                    mag_after: mag_before,
                });
                continue;
            }
            // Round half-away on the magnitude: (mag + 2^{s-1}) >> s,
            // computed as (mag >> s) + carry with carry = bit s-1 of mag.
            let round_up = fixed_bit(&mag, s - 1);
            let mut rounded = fixed_shr(&mag, s);
            if round_up {
                fixed_add_one(&mut rounded);
            }
            let mag_after = fixed_to_f64(&rounded);
            if fixed_is_zero(&rounded) {
                for c in 0..k {
                    lanes[c * n + j] = 0;
                }
                out.push(Rescaled {
                    neg: false,
                    mag_before,
                    mag_after,
                });
                continue;
            }
            // d = |rounded·2^s − mag|: with low = mag mod 2^s this is
            // 2^s − low when rounding up (low ≥ 2^{s-1} > 0, and a set
            // bit s-1 of mag bounds s below the fixed width), low
            // otherwise.
            let low = fixed_low_bits(&mag, s);
            let d = if round_up {
                let mut p = fixed_pow2(s);
                fixed_sub(&mut p, &low);
                p
            } else {
                low
            };
            // Signed update: N'·2^s = N + σ·d with σ = sign(N) when
            // rounding up (away from zero) and −sign(N) otherwise, so
            // r' = (r ± d_i)·2^{-s} per channel.
            let add_d = neg != round_up;
            for c in 0..k {
                let bar = &self.barrett[c];
                let mut dm = 0u64;
                for (base, &limb) in self.limb_base[c].iter().zip(&d) {
                    if limb != 0 {
                        dm = bar.add(dm, base.mul(bar, bar.reduce(limb)));
                    }
                }
                let r = lanes[c * n + j];
                let t = if add_d { bar.add(r, dm) } else { bar.sub(r, dm) };
                lanes[c * n + j] = inv[c].mul_inv_pow2(bar, t, s);
            }
            out.push(Rescaled {
                neg,
                mag_before,
                mag_after,
            });
        }
        out
    }

    /// MAC-carrying variant of [`CrtContext::rescale_batch`]: rescales the
    /// value lanes exactly as `rescale_batch` does and updates the
    /// companion MAC lanes `mac_i = α_i·r_i mod m_i` homomorphically
    /// through the same Definition-4 offset —
    /// `mac'_i = (mac_i ± α_i·d_i)·2^{-s} mod m_i` — so `mac'_i = α_i·r'_i`
    /// holds exactly afterwards. The MAC is never recomputed from the value
    /// (that would launder a corrupted value into a valid MAC); a value
    /// corrupted before the sweep still fails its check after it.
    /// Rounding to zero zeroes the MAC lanes too (`α·0 = 0`).
    ///
    /// Only the residue-domain fast path supports the homomorphic update
    /// (`2^{-s}` needs every modulus odd and the set inside the fixed
    /// window); the BigUint fallback re-encodes from the reconstructed
    /// integer, which is exactly the laundering the MAC exists to prevent,
    /// so exotic modulus sets are rejected loudly here and at admission
    /// (`registry::tier_covers` enforces the same precondition).
    pub fn rescale_batch_with_mac(
        &self,
        lanes: &mut [u64],
        macs: &mut [u64],
        alpha: &[u64],
        n: usize,
        shifts: &[u32],
    ) -> Vec<Rescaled> {
        let k = self.k();
        assert_eq!(lanes.len(), k * n, "lanes must be k×n channel-major");
        assert_eq!(macs.len(), k * n, "MAC lanes must be k×n channel-major");
        assert_eq!(alpha.len(), k, "one MAC key residue per channel");
        assert_eq!(shifts.len(), n, "one shift per element");
        let inv = self
            .inv_pow2
            .as_ref()
            .filter(|_| self.fixed_ok)
            .expect("authenticated rescale requires the odd-moduli residue-domain fast path");
        let mut out = Vec::with_capacity(n);
        for (j, &s) in shifts.iter().enumerate() {
            let acc = self.fixed_accumulate(|c| lanes[c * n + j]);
            let neg = fixed_cmp(&acc, &self.half_limbs) != std::cmp::Ordering::Less;
            let mag = if neg {
                let mut m = self.m_limbs;
                fixed_sub(&mut m, &acc);
                m
            } else {
                acc
            };
            let mag_before = fixed_to_f64(&mag);
            if s == 0 {
                out.push(Rescaled {
                    neg: neg && !fixed_is_zero(&mag),
                    mag_before,
                    mag_after: mag_before,
                });
                continue;
            }
            let round_up = fixed_bit(&mag, s - 1);
            let mut rounded = fixed_shr(&mag, s);
            if round_up {
                fixed_add_one(&mut rounded);
            }
            let mag_after = fixed_to_f64(&rounded);
            if fixed_is_zero(&rounded) {
                for c in 0..k {
                    lanes[c * n + j] = 0;
                    macs[c * n + j] = 0;
                }
                out.push(Rescaled {
                    neg: false,
                    mag_before,
                    mag_after,
                });
                continue;
            }
            let low = fixed_low_bits(&mag, s);
            let d = if round_up {
                let mut p = fixed_pow2(s);
                fixed_sub(&mut p, &low);
                p
            } else {
                low
            };
            let add_d = neg != round_up;
            for c in 0..k {
                let bar = &self.barrett[c];
                let mut dm = 0u64;
                for (base, &limb) in self.limb_base[c].iter().zip(&d) {
                    if limb != 0 {
                        dm = bar.add(dm, base.mul(bar, bar.reduce(limb)));
                    }
                }
                let r = lanes[c * n + j];
                let t = if add_d { bar.add(r, dm) } else { bar.sub(r, dm) };
                lanes[c * n + j] = inv[c].mul_inv_pow2(bar, t, s);
                // Same offset, scaled by the channel key: α·(N'·2^s − N)
                // folds as α_c·d_c, so the MAC stays α_c·r'_c exactly.
                let adm = bar.mul(alpha[c], dm);
                let mr = macs[c * n + j];
                let mt = if add_d { bar.add(mr, adm) } else { bar.sub(mr, adm) };
                macs[c * n + j] = inv[c].mul_inv_pow2(bar, mt, s);
            }
            out.push(Rescaled {
                neg,
                mag_before,
                mag_after,
            });
        }
        out
    }

    /// BigUint mirror of [`CrtContext::rescale_batch`] (exotic modulus
    /// sets): reconstruct, round, re-encode, negate — exactly the scalar
    /// normalization tail, element by element.
    fn rescale_batch_slow(&self, lanes: &mut [u64], n: usize, shifts: &[u32]) -> Vec<Rescaled> {
        let mut out = Vec::with_capacity(n);
        for (j, &s) in shifts.iter().enumerate() {
            let rv = self.gather(lanes, n, j);
            let (neg, mag) = self.reconstruct_signed(&rv);
            let mag_before = mag.to_f64();
            let rounded = if s == 0 {
                mag
            } else {
                mag.add(&BigUint::one().shl(s - 1)).shr(s)
            };
            let mag_after = rounded.to_f64();
            let keep_sign = neg && !rounded.is_zero();
            if s != 0 {
                let r = self.encode(&rounded);
                for (c, (&ri, &m)) in r.r.iter().zip(&self.moduli).enumerate() {
                    lanes[c * n + j] = if keep_sign && ri != 0 { m - ri } else { ri };
                }
            }
            out.push(Rescaled {
                neg: keep_sign,
                mag_before,
                mag_after,
            });
        }
        out
    }

    /// Mixed-radix digits (d_0..d_{k-1}) with
    /// `N = d_0 + d_1·m_0 + d_2·m_0·m_1 + …` — enables magnitude comparison
    /// without full CRT (paper §II-D / [20]).
    pub fn mixed_radix(&self, r: &ResidueVec) -> Vec<u64> {
        let k = self.k();
        let mut x: Vec<u64> = r.r.clone();
        let mut digits = vec![0u64; k];
        for j in 0..k {
            digits[j] = x[j];
            // Propagate: x_i := (x_i - d_j) * m_j^{-1} mod m_i for i > j.
            for i in (j + 1)..k {
                let b = &self.barrett[i];
                let dj = digits[j] % self.moduli[i];
                let diff = b.sub(x[i], dj);
                x[i] = b.mul(diff, self.mrc_inv[i][j]);
            }
        }
        digits
    }

    /// Compare two residue vectors by magnitude via mixed-radix digits
    /// (most-significant digit last).
    pub fn compare(&self, a: &ResidueVec, b: &ResidueVec) -> std::cmp::Ordering {
        let da = self.mixed_radix(a);
        let db = self.mixed_radix(b);
        for (x, y) in da.iter().zip(&db).rev() {
            match x.cmp(y) {
                std::cmp::Ordering::Equal => continue,
                o => return o,
            }
        }
        std::cmp::Ordering::Equal
    }

    /// Re-encode a big integer into residues (normalization engine step iv).
    pub fn encode(&self, n: &BigUint) -> ResidueVec {
        ResidueVec::encode_big(n, &self.moduli)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::DEFAULT_MODULI;
    use crate::util::proptest::{check, check_with};

    fn ctx() -> CrtContext {
        CrtContext::new(&DEFAULT_MODULI)
    }

    #[test]
    fn inv_mod_known() {
        assert_eq!(inv_mod(3, 7), 5); // 3*5=15≡1
        assert_eq!(inv_mod(1, 97), 1);
        for a in 1..97u64 {
            assert_eq!(a * inv_mod(a, 97) % 97, 1);
        }
    }

    #[test]
    #[should_panic(expected = "not invertible")]
    fn inv_mod_non_coprime_panics() {
        inv_mod(6, 9);
    }

    #[test]
    fn reconstruct_small_values() {
        let c = ctx();
        for n in [0u64, 1, 2, 65520, 65521, 1_000_000_007] {
            let r = ResidueVec::encode_u64(n, &DEFAULT_MODULI);
            assert_eq!(c.reconstruct(&r).to_u64(), Some(n), "n={n}");
        }
    }

    #[test]
    fn reconstruct_three_moduli_exhaustive() {
        let c = CrtContext::new(&[3, 5, 7]);
        for n in 0..105u64 {
            let r = ResidueVec::encode_u64(n, &[3, 5, 7]);
            assert_eq!(c.reconstruct(&r).to_u64(), Some(n));
        }
    }

    #[test]
    fn prop_crt_roundtrip_u128() {
        let c = ctx();
        check("crt-roundtrip", |rng| {
            let n = ((rng.next_u64() as u128) << 60) | rng.next_u64() as u128;
            let big = BigUint::from_u128(n);
            let r = c.encode(&big);
            crate::prop_assert!(
                c.reconstruct(&r) == big,
                "roundtrip failed n={n}"
            );
            Ok(())
        });
    }

    #[test]
    fn signed_reconstruction() {
        let c = ctx();
        // Encode -5 as M - 5.
        let m_minus_5 = c.big_m.sub(&BigUint::from_u64(5));
        let r = c.encode(&m_minus_5);
        let (neg, mag) = c.reconstruct_signed(&r);
        assert!(neg);
        assert_eq!(mag.to_u64(), Some(5));
        let (neg, mag) = c.reconstruct_signed(&c.encode(&BigUint::from_u64(5)));
        assert!(!neg);
        assert_eq!(mag.to_u64(), Some(5));
    }

    #[test]
    fn mixed_radix_reconstructs() {
        let c = CrtContext::new(&[3, 5, 7, 11]);
        for n in [0u64, 1, 104, 1000, 1154] {
            let r = ResidueVec::encode_u64(n, &[3, 5, 7, 11]);
            let d = c.mixed_radix(&r);
            // N = d0 + d1*3 + d2*15 + d3*105
            let got = d[0] + d[1] * 3 + d[2] * 15 + d[3] * 105;
            assert_eq!(got, n, "n={n} digits={d:?}");
        }
    }

    #[test]
    fn prop_mixed_radix_comparison_matches_crt() {
        let c = ctx();
        check_with("mrc-compare", 128, |rng| {
            let a128 = ((rng.next_u64() as u128) << 50) | rng.next_u64() as u128;
            let b128 = ((rng.next_u64() as u128) << 50) | rng.next_u64() as u128;
            let ra = c.encode(&BigUint::from_u128(a128));
            let rb = c.encode(&BigUint::from_u128(b128));
            crate::prop_assert!(
                c.compare(&ra, &rb) == a128.cmp(&b128),
                "compare mismatch a={a128} b={b128}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_fixed_reconstruction_matches_slow_path() {
        let c = ctx();
        assert!(c.fixed_ok, "default set must take the fast path");
        check("crt-fast-vs-slow", |rng| {
            let n = ((rng.next_u64() as u128) << 63) | rng.next_u64() as u128;
            let r = c.encode(&BigUint::from_u128(n));
            let fast = c.reconstruct(&r);
            let slow = c.reconstruct_slow(&r);
            crate::prop_assert!(fast == slow, "fast != slow for n={n}");
            Ok(())
        });
    }

    #[test]
    fn fixed_reconstruction_extremes() {
        let c = ctx();
        // All residues at m-1 (the largest representable pre-reduction sum).
        let r = ResidueVec {
            r: c.moduli.iter().map(|&m| m - 1).collect(),
        };
        assert_eq!(c.reconstruct(&r), c.reconstruct_slow(&r));
        // Zero.
        let z = ResidueVec::zero(c.k());
        assert!(c.reconstruct(&z).is_zero());
        // M - 1.
        let m1 = c.big_m.sub(&BigUint::one());
        let r = c.encode(&m1);
        assert_eq!(c.reconstruct(&r), m1);
    }

    #[test]
    fn signed_boundary_at_half_m() {
        // The M-complement sign convention splits [0, M) at M/2: values
        // below M/2 are non-negative, values at/above it are negative.
        let c = ctx();
        let half = c.big_m.shr(1);
        // M/2 - 1: the largest positive value.
        let below = half.sub(&BigUint::one());
        let (neg, mag) = c.reconstruct_signed(&c.encode(&below));
        assert!(!neg, "M/2 - 1 must be non-negative");
        assert_eq!(mag, below);
        // M/2 exactly: first negative value, magnitude M - M/2.
        let (neg, mag) = c.reconstruct_signed(&c.encode(&half));
        assert!(neg, "M/2 must be negative");
        assert_eq!(mag, c.big_m.sub(&half));
        // M/2 + 1.
        let above = half.add(&BigUint::one());
        let (neg, mag) = c.reconstruct_signed(&c.encode(&above));
        assert!(neg);
        assert_eq!(mag, c.big_m.sub(&above));
    }

    #[test]
    fn prop_signed_roundtrip_both_signs() {
        // Random magnitudes below M/2 must round-trip exactly through the
        // M-complement encoding in both signs.
        let c = ctx();
        check_with("crt-signed-roundtrip", 128, |rng| {
            // Force nonzero: 0 has no negative encoding (M - 0 wraps to 0).
            let n = (((rng.next_u64() as u128) << 58) | rng.next_u64() as u128) | 1;
            let mag = BigUint::from_u128(n);
            // Positive.
            let (neg, back) = c.reconstruct_signed(&c.encode(&mag));
            crate::prop_assert!(!neg && back == mag, "positive roundtrip n={n}");
            // Negative: encode as M - n.
            let enc = c.big_m.sub(&mag);
            let (neg, back) = c.reconstruct_signed(&c.encode(&enc));
            crate::prop_assert!(neg && back == mag, "negative roundtrip n={n}");
            Ok(())
        });
    }

    #[test]
    fn prop_batch_reconstruction_matches_per_element() {
        // reconstruct_batch / reconstruct_signed_batch over a channel-major
        // block must be bit-identical to per-element reconstruct /
        // reconstruct_signed — including all-zero outputs, sign-boundary
        // values and worst-case residues.
        let c = ctx();
        let k = c.k();
        check_with("crt-batch-vs-scalar", 64, |rng| {
            let n = rng.below(17) as usize; // includes n = 0
            let mut lanes = vec![0u64; k * n];
            for j in 0..n {
                // Mix: zero, small, random-signed-range, worst-case m-1.
                match rng.below(4) {
                    0 => {}
                    1 => {
                        for (ch, &m) in c.moduli.iter().enumerate() {
                            lanes[ch * n + j] = rng.below(m);
                        }
                    }
                    2 => {
                        for (ch, &m) in c.moduli.iter().enumerate() {
                            lanes[ch * n + j] = m - 1;
                        }
                    }
                    _ => {
                        let v = rng.next_u64();
                        for (ch, &m) in c.moduli.iter().enumerate() {
                            lanes[ch * n + j] = v % m;
                        }
                    }
                }
            }
            let batch = c.reconstruct_batch(&lanes, n);
            let signed = c.reconstruct_signed_batch(&lanes, n);
            crate::prop_assert!(batch.len() == n && signed.len() == n, "lengths");
            for j in 0..n {
                let rv = ResidueVec {
                    r: (0..k).map(|ch| lanes[ch * n + j]).collect(),
                };
                crate::prop_assert!(batch[j] == c.reconstruct(&rv), "batch j={j}");
                crate::prop_assert!(
                    signed[j] == c.reconstruct_signed(&rv),
                    "signed batch j={j}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn batch_with_reader_matches_lane_batch() {
        let c = ctx();
        let k = c.k();
        let n = 9;
        let mut lanes = vec![0u64; k * n];
        for (i, v) in lanes.iter_mut().enumerate() {
            *v = (i as u64 * 2654435761) % c.moduli[i / n];
        }
        let via_lanes = c.reconstruct_signed_batch(&lanes, n);
        let via_reader = c.reconstruct_signed_batch_with(n, |ch, j| lanes[ch * n + j]);
        assert_eq!(via_lanes.len(), via_reader.len());
        for (a, b) in via_lanes.iter().zip(&via_reader) {
            assert_eq!(a, b);
        }
    }

    #[test]
    #[should_panic(expected = "channel-major")]
    fn batch_rejects_misshaped_lanes() {
        let c = ctx();
        c.reconstruct_batch(&[0u64; 7], 2);
    }

    /// Independent scalar specification of one rescale: reconstruct,
    /// round half-away on the magnitude, re-encode, negate.
    fn scalar_rescale(c: &CrtContext, rv: &ResidueVec, s: u32) -> (ResidueVec, bool, BigUint) {
        let (neg, mag) = c.reconstruct_signed(rv);
        let rounded = if s == 0 {
            mag
        } else {
            mag.add(&BigUint::one().shl(s - 1)).shr(s)
        };
        let mut r = c.encode(&rounded);
        let keep = neg && !rounded.is_zero();
        if keep {
            r = ResidueVec {
                r: r.r
                    .iter()
                    .zip(&c.moduli)
                    .map(|(&ri, &m)| if ri == 0 { 0 } else { m - ri })
                    .collect(),
            };
        }
        (r, keep, rounded)
    }

    fn random_signed_lanes(c: &CrtContext, rng: &mut crate::util::prng::Rng, n: usize) -> Vec<u64> {
        let k = c.k();
        let mut lanes = vec![0u64; k * n];
        for j in 0..n {
            match rng.below(5) {
                0 => {} // exact zero
                1 => {
                    // Small magnitude, either sign (M-complement).
                    let v = rng.next_u64() >> (32 + rng.below(30));
                    let enc = if rng.bool() && v != 0 {
                        c.big_m.sub(&BigUint::from_u64(v))
                    } else {
                        BigUint::from_u64(v)
                    };
                    let r = c.encode(&enc);
                    for (ch, &ri) in r.r.iter().enumerate() {
                        lanes[ch * n + j] = ri;
                    }
                }
                2 => {
                    // Sign boundary neighbourhood: M/2 ± small.
                    let half = c.big_m.shr(1);
                    let enc = if rng.bool() {
                        half.add_u64(rng.below(3))
                    } else {
                        half.sub(&BigUint::from_u64(rng.below(3) + 1))
                    };
                    let r = c.encode(&enc);
                    for (ch, &ri) in r.r.iter().enumerate() {
                        lanes[ch * n + j] = ri;
                    }
                }
                _ => {
                    // Arbitrary residues (a uniform value mod M).
                    for (ch, &m) in c.moduli.iter().enumerate() {
                        lanes[ch * n + j] = rng.below(m);
                    }
                }
            }
        }
        lanes
    }

    fn check_rescale_matches_scalar(c: &CrtContext, rng: &mut crate::util::prng::Rng) {
        let k = c.k();
        let n = rng.below(13) as usize; // includes n = 0
        let lanes = random_signed_lanes(c, rng, n);
        let shifts: Vec<u32> = (0..n)
            .map(|_| match rng.below(5) {
                0 => 0,
                1 => 1 + rng.below(8) as u32,
                2 => 1 + rng.below(64) as u32,
                3 => 1 + rng.below(c.big_m.bit_length() as u64) as u32,
                // Past the top: everything rounds to zero.
                _ => c.big_m.bit_length() + 1 + rng.below(64) as u32,
            })
            .collect();
        let mut got = lanes.clone();
        let outcomes = c.rescale_batch(&mut got, n, &shifts);
        assert_eq!(outcomes.len(), n);
        for j in 0..n {
            let rv = ResidueVec {
                r: (0..k).map(|ch| lanes[ch * n + j]).collect(),
            };
            let (want, want_neg, rounded) = scalar_rescale(c, &rv, shifts[j]);
            let got_rv = ResidueVec {
                r: (0..k).map(|ch| got[ch * n + j]).collect(),
            };
            assert_eq!(got_rv, want, "residues j={j} s={}", shifts[j]);
            assert_eq!(outcomes[j].neg, want_neg, "sign j={j}");
            assert_eq!(
                outcomes[j].mag_after.to_bits(),
                rounded.to_f64().to_bits(),
                "mag_after j={j}"
            );
            let (_, mag_before) = c.reconstruct_signed(&rv);
            assert_eq!(
                outcomes[j].mag_before.to_bits(),
                mag_before.to_f64().to_bits(),
                "mag_before j={j}"
            );
        }
    }

    #[test]
    fn prop_rescale_batch_matches_scalar_default_moduli() {
        let c = ctx();
        assert!(c.inv_pow2.is_some(), "default set is odd");
        check_with("crt-rescale-default", 64, |rng| {
            check_rescale_matches_scalar(&c, rng);
            Ok(())
        });
    }

    #[test]
    fn prop_rescale_batch_matches_scalar_random_prime_moduli() {
        use crate::rns::moduli::generate_prime_moduli;
        check_with("crt-rescale-random-moduli", 24, |rng| {
            let k = 3 + rng.below(5) as usize;
            let width = 8 + rng.below(23) as u32; // 8..=30-bit lanes
            let c = CrtContext::new(&generate_prime_moduli(k, width));
            check_rescale_matches_scalar(&c, rng);
            Ok(())
        });
    }

    #[test]
    fn rescale_batch_even_modulus_falls_back() {
        // 2^16 is coprime to the odd primes but has no inverse of 2, so
        // the residue-domain fast path must yield to the BigUint mirror —
        // results stay bit-identical to the scalar specification.
        let c = CrtContext::new(&[65536, 65521, 65519]);
        assert!(c.inv_pow2.is_none());
        let mut rng = crate::util::prng::Rng::new(77);
        for _ in 0..16 {
            check_rescale_matches_scalar(&c, &mut rng);
        }
    }

    #[test]
    fn rescale_batch_half_rounds_away_from_zero_both_signs() {
        let c = ctx();
        let n = 2;
        // +3 and -3, shifted by 1: round(1.5) = 2 away from zero.
        let pos = c.encode(&BigUint::from_u64(3));
        let neg = c.encode(&c.big_m.sub(&BigUint::from_u64(3)));
        let k = c.k();
        let mut lanes = vec![0u64; k * n];
        for ch in 0..k {
            lanes[ch * n] = pos.r[ch];
            lanes[ch * n + 1] = neg.r[ch];
        }
        let outcomes = c.rescale_batch(&mut lanes, n, &[1, 1]);
        let (sgn0, m0) = c.reconstruct_signed(&ResidueVec {
            r: (0..k).map(|ch| lanes[ch * n]).collect(),
        });
        let (sgn1, m1) = c.reconstruct_signed(&ResidueVec {
            r: (0..k).map(|ch| lanes[ch * n + 1]).collect(),
        });
        assert!(!sgn0 && m0.to_u64() == Some(2), "round(3/2) = 2");
        assert!(sgn1 && m1.to_u64() == Some(2), "round(-3/2) = -2");
        assert!(!outcomes[0].neg && outcomes[1].neg);
        assert_eq!(outcomes[0].mag_after, 2.0);
    }

    #[test]
    #[should_panic(expected = "channel-major")]
    fn rescale_batch_rejects_misshaped_lanes() {
        let c = ctx();
        c.rescale_batch(&mut [0u64; 7], 2, &[1, 1]);
    }

    #[test]
    fn prop_rescale_with_mac_tracks_value_lanes_exactly() {
        // The authenticated rescale must (a) leave the value lanes
        // bit-identical to the plain `rescale_batch`, and (b) keep the MAC
        // invariant mac_i = α_i·r_i mod m_i exact through the event — the
        // homomorphic update, never a recompute.
        let c = ctx();
        let k = c.k();
        check_with("crt-rescale-mac", 48, |rng| {
            let n = rng.below(11) as usize;
            let alpha: Vec<u64> = c.moduli.iter().map(|&m| 1 + rng.below(m - 1)).collect();
            let lanes = random_signed_lanes(&c, rng, n);
            let shifts: Vec<u32> = (0..n)
                .map(|_| match rng.below(4) {
                    0 => 0,
                    1 => 1 + rng.below(16) as u32,
                    2 => 1 + rng.below(c.big_m.bit_length() as u64) as u32,
                    _ => c.big_m.bit_length() + 1 + rng.below(32) as u32,
                })
                .collect();
            let mut macs = vec![0u64; k * n];
            for ch in 0..k {
                let bar = &c.barrett[ch];
                for j in 0..n {
                    macs[ch * n + j] = bar.mul(alpha[ch], lanes[ch * n + j]);
                }
            }
            let mut plain = lanes.clone();
            let want = c.rescale_batch(&mut plain, n, &shifts);
            let mut got_lanes = lanes.clone();
            let got = c.rescale_batch_with_mac(&mut got_lanes, &mut macs, &alpha, n, &shifts);
            crate::prop_assert!(got == want, "outcomes diverge");
            crate::prop_assert!(got_lanes == plain, "value lanes diverge");
            for ch in 0..k {
                let bar = &c.barrett[ch];
                for j in 0..n {
                    crate::prop_assert!(
                        macs[ch * n + j] == bar.mul(alpha[ch], got_lanes[ch * n + j]),
                        "MAC invariant broken ch={ch} j={j}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "odd-moduli")]
    fn rescale_with_mac_rejects_even_modulus_sets() {
        let c = CrtContext::new(&[65536, 65521, 65519]);
        let mut lanes = vec![0u64; 3];
        let mut macs = vec![0u64; 3];
        c.rescale_batch_with_mac(&mut lanes, &mut macs, &[1, 1, 1], 1, &[1]);
    }

    #[test]
    fn homomorphism_through_reconstruction() {
        // CRT(rX ⊙ rY) == CRT(rX)*CRT(rY) for products < M (Theorem 1 core).
        let c = ctx();
        let a = 0xdead_beef_u64;
        let b = 0xcafe_babe_u64;
        let ra = ResidueVec::encode_u64(a, &DEFAULT_MODULI);
        let rb = ResidueVec::encode_u64(b, &DEFAULT_MODULI);
        let rz = ra.mul(&rb, &c.barrett);
        assert_eq!(
            c.reconstruct(&rz).to_u128(),
            Some(a as u128 * b as u128)
        );
    }
}
