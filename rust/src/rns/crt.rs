//! Chinese Remainder Theorem reconstruction (paper §III-A semantics, §VI-E
//! normalization engine) and mixed-radix conversion (the reconstruction-free
//! comparison alternative discussed in §II-D).
//!
//! `CrtContext` precomputes, per channel, `M_i = M / m_i` and
//! `inv_i = M_i^{-1} mod m_i`, so reconstruction is
//! `N = Σ r_i · inv_i · M_i  mod M` — exactly the structure a pipelined
//! CRT engine evaluates.

use super::barrett::{barrett_set, Barrett};
use super::moduli::{composite_modulus, is_pairwise_coprime};
use super::residue::ResidueVec;
use crate::bigint::BigUint;

/// Extended gcd on i128: returns (g, x, y) with a·x + b·y = g.
fn egcd(a: i128, b: i128) -> (i128, i128, i128) {
    if b == 0 {
        (a, 1, 0)
    } else {
        let (g, x, y) = egcd(b, a % b);
        (g, y, x - (a / b) * y)
    }
}

/// Modular inverse of `a` mod `m` (panics if not coprime).
pub fn inv_mod(a: u64, m: u64) -> u64 {
    let (g, x, _) = egcd(a as i128, m as i128);
    assert!(g == 1, "inv_mod: {a} not invertible mod {m}");
    (x.rem_euclid(m as i128)) as u64
}

/// Precomputed CRT reconstruction context for a modulus set.
#[derive(Clone, Debug)]
pub struct CrtContext {
    pub moduli: Vec<u64>,
    pub barrett: Vec<Barrett>,
    /// Composite modulus M = Π m_i.
    pub big_m: BigUint,
    /// Precombined per-channel term basis: T_i = (inv_i · M_i) mod M.
    /// Reconstruction is then N = Σ r_i·T_i mod M.
    term: Vec<BigUint>,
    /// Mixed-radix factors m_j^{-1} mod m_i for j < i (lower-triangular).
    mrc_inv: Vec<Vec<u64>>,
    /// §Perf fast path: `term[i]` as fixed little-endian limbs, all padded
    /// to a common width (`fixed_limbs`), so reconstruction runs over
    /// stack arrays with no heap allocation.
    term_limbs: Vec<[u64; FIXED_LIMBS]>,
    /// M as fixed limbs.
    m_limbs: [u64; FIXED_LIMBS],
    /// ⌊M/2⌋ — the M-complement sign boundary, hoisted out of every
    /// signed reconstruction (it used to be recomputed per call).
    half: BigUint,
    /// ⌊M/2⌋ as fixed limbs for the stack-array sign test.
    half_limbs: [u64; FIXED_LIMBS],
    /// True when k and bit sizes fit the fixed-width fast path.
    fixed_ok: bool,
}

/// Fixed reconstruction width: 5×64 = 320 bits covers M up to ~2^288 plus
/// the Σ rᵢ·Tᵢ headroom (k ≤ 16 channels of 32-bit moduli).
const FIXED_LIMBS: usize = 5;

#[inline]
fn to_fixed(b: &BigUint) -> Option<[u64; FIXED_LIMBS]> {
    if b.limbs.len() > FIXED_LIMBS {
        return None;
    }
    let mut out = [0u64; FIXED_LIMBS];
    out[..b.limbs.len()].copy_from_slice(&b.limbs);
    Some(out)
}

/// acc += t * r (fixed width, carry-propagating). Returns overflow.
#[inline]
fn fixed_mul_acc(acc: &mut [u64; FIXED_LIMBS], t: &[u64; FIXED_LIMBS], r: u64) -> bool {
    let mut carry: u128 = 0;
    for (a, &tl) in acc.iter_mut().zip(t) {
        let v = *a as u128 + (tl as u128) * (r as u128) + carry;
        *a = v as u64;
        carry = v >> 64;
    }
    carry != 0
}

/// Compare fixed-width values.
#[inline]
fn fixed_cmp(a: &[u64; FIXED_LIMBS], b: &[u64; FIXED_LIMBS]) -> std::cmp::Ordering {
    for (al, bl) in a.iter().zip(b).rev() {
        match al.cmp(bl) {
            std::cmp::Ordering::Equal => continue,
            o => return o,
        }
    }
    std::cmp::Ordering::Equal
}

/// a -= b (fixed width; caller guarantees a >= b).
#[inline]
fn fixed_sub(a: &mut [u64; FIXED_LIMBS], b: &[u64; FIXED_LIMBS]) {
    let mut borrow = 0u64;
    for (al, &bl) in a.iter_mut().zip(b) {
        let (d1, b1) = al.overflowing_sub(bl);
        let (d2, b2) = d1.overflowing_sub(borrow);
        *al = d2;
        borrow = (b1 as u64) + (b2 as u64);
    }
    debug_assert_eq!(borrow, 0);
}

impl CrtContext {
    /// Build a context; validates pairwise coprimality.
    pub fn new(moduli: &[u64]) -> CrtContext {
        assert!(!moduli.is_empty());
        assert!(
            is_pairwise_coprime(moduli),
            "moduli must be pairwise coprime"
        );
        let big_m = composite_modulus(moduli);
        let m_over: Vec<BigUint> = moduli
            .iter()
            .map(|&mi| big_m.div_rem_u64(mi).0)
            .collect();
        let inv: Vec<u64> = moduli
            .iter()
            .zip(&m_over)
            .map(|(&mi, mo)| inv_mod(mo.rem_u64(mi), mi))
            .collect();
        let term: Vec<BigUint> = m_over
            .iter()
            .zip(&inv)
            .map(|(mo, &iv)| mo.mul_u64(iv).rem_big(&big_m))
            .collect();
        let mrc_inv = (0..moduli.len())
            .map(|i| {
                (0..i)
                    .map(|j| inv_mod(moduli[j] % moduli[i], moduli[i]))
                    .collect()
            })
            .collect();
        // §Perf fixed-width tables: valid when M (and the Σ rᵢTᵢ headroom
        // of k · max(m) beyond it) fits FIXED_LIMBS.
        let headroom_bits =
            big_m.bit_length() + 64 + (moduli.len() as f64).log2().ceil() as u32;
        let fixed_ok = headroom_bits < (FIXED_LIMBS as u32) * 64;
        let term_limbs = term
            .iter()
            .map(|t| to_fixed(t).unwrap_or([0; FIXED_LIMBS]))
            .collect();
        let m_limbs = to_fixed(&big_m).unwrap_or([0; FIXED_LIMBS]);
        let half = big_m.shr(1);
        let half_limbs = to_fixed(&half).unwrap_or([0; FIXED_LIMBS]);
        CrtContext {
            barrett: barrett_set(moduli),
            moduli: moduli.to_vec(),
            big_m,
            term,
            mrc_inv,
            term_limbs,
            m_limbs,
            half,
            half_limbs,
            fixed_ok,
        }
    }

    /// Number of channels.
    pub fn k(&self) -> usize {
        self.moduli.len()
    }

    /// The fixed-width accumulation core: `acc = Σ read(i)·Tᵢ mod M` over
    /// a stack array. `read(i)` supplies channel `i`'s residue, so batch
    /// callers can stream residues straight out of channel-major lanes
    /// with no per-output `ResidueVec` gather.
    #[inline]
    fn fixed_accumulate(&self, mut read: impl FnMut(usize) -> u64) -> [u64; FIXED_LIMBS] {
        let mut acc = [0u64; FIXED_LIMBS];
        for (i, term) in self.term_limbs.iter().enumerate() {
            let ri = read(i);
            if ri != 0 {
                let overflow = fixed_mul_acc(&mut acc, term, ri);
                debug_assert!(!overflow, "fixed-width CRT overflow");
            }
        }
        self.fixed_reduce_mod_m(&mut acc);
        acc
    }

    /// Reduce a fixed-width `acc < k·max(m)·M` (≤ M << ~20 bits) mod M by
    /// conditional subtractions of shifted M — no heap allocation, no
    /// general division.
    fn fixed_reduce_mod_m(&self, acc: &mut [u64; FIXED_LIMBS]) {
        // Find the highest shift where (M << s) could still be ≤ acc.
        let m_bits = self.big_m.bit_length();
        let acc_bits = {
            let mut bits = 0;
            for (i, &limb) in acc.iter().enumerate().rev() {
                if limb != 0 {
                    bits = i as u32 * 64 + (64 - limb.leading_zeros());
                    break;
                }
            }
            bits
        };
        if acc_bits >= m_bits {
            let mut s = acc_bits - m_bits;
            loop {
                // shifted = M << s (fixed width; s ≤ ~24 so it fits).
                let mut shifted = [0u64; FIXED_LIMBS];
                let limb_s = (s / 64) as usize;
                let bit_s = s % 64;
                for i in 0..FIXED_LIMBS - limb_s {
                    let lo = self.m_limbs[i] << bit_s;
                    let hi = if bit_s > 0 && i > 0 {
                        self.m_limbs[i - 1] >> (64 - bit_s)
                    } else {
                        0
                    };
                    shifted[i + limb_s] = lo | hi;
                }
                while fixed_cmp(acc, &shifted) != std::cmp::Ordering::Less {
                    fixed_sub(acc, &shifted);
                }
                if s == 0 {
                    break;
                }
                s -= 1;
            }
        }
    }

    /// Apply the M-complement sign convention to a fixed-width `N ∈ [0, M)`
    /// using the precomputed ⌊M/2⌋ limbs (no BigUint compare, no per-call
    /// shift).
    #[inline]
    fn signed_from_fixed(&self, acc: [u64; FIXED_LIMBS]) -> (bool, BigUint) {
        if fixed_cmp(&acc, &self.half_limbs) != std::cmp::Ordering::Less {
            let mut mag = self.m_limbs;
            fixed_sub(&mut mag, &acc);
            (true, BigUint::from_limbs(mag.to_vec()))
        } else {
            (false, BigUint::from_limbs(acc.to_vec()))
        }
    }

    /// Sign convention on a BigUint `N ∈ [0, M)` (slow-path mirror of
    /// [`CrtContext::signed_from_fixed`]).
    #[inline]
    fn signed_from_big(&self, n: BigUint) -> (bool, BigUint) {
        if n >= self.half {
            (true, self.big_m.sub(&n))
        } else {
            (false, n)
        }
    }

    /// CRT reconstruction: the unique `N ∈ [0, M)` with `N ≡ r_i (mod m_i)`.
    ///
    /// §Perf: the default path accumulates `Σ rᵢ·Tᵢ` in a fixed-width
    /// stack array and reduces mod M by (at most k) conditional
    /// subtractions of shifted M — no heap allocation, no general
    /// division. Falls back to BigUint for exotic modulus sets.
    pub fn reconstruct(&self, r: &ResidueVec) -> BigUint {
        assert_eq!(r.k(), self.k());
        if !self.fixed_ok {
            return self.reconstruct_slow(r);
        }
        let acc = self.fixed_accumulate(|i| r.r[i]);
        BigUint::from_limbs(acc.to_vec())
    }

    /// Allocation-heavy fallback reconstruction (arbitrary modulus sets).
    fn reconstruct_slow(&self, r: &ResidueVec) -> BigUint {
        let mut acc = BigUint::zero();
        for (i, &ri) in r.r.iter().enumerate() {
            if ri != 0 {
                acc = acc.add(&self.term[i].mul_u64(ri));
            }
        }
        acc.rem_big(&self.big_m)
    }

    /// Signed reconstruction under the symmetric convention: values in
    /// `[0, M/2)` are non-negative, `[M/2, M)` map to `N - M` (standard RNS
    /// sign handling; HRFNA encodes negatives this way).
    pub fn reconstruct_signed(&self, r: &ResidueVec) -> (bool, BigUint) {
        assert_eq!(r.k(), self.k());
        if !self.fixed_ok {
            let n = self.reconstruct_slow(r);
            return self.signed_from_big(n);
        }
        self.signed_from_fixed(self.fixed_accumulate(|i| r.r[i]))
    }

    /// Batched CRT over channel-major lanes (`lanes[c*n + j]` is channel
    /// `c` of output `j` — a [`super::plane::ResiduePlane`] buffer or any
    /// `k × n` residue block). The per-modulus `(invᵢ·Mᵢ) mod M` term
    /// table, the fixed-limb scratch discipline and the reduction state
    /// are hoisted out of the per-output loop — no per-output
    /// `ResidueVec`, no per-output sign-boundary recompute.
    pub fn reconstruct_batch(&self, lanes: &[u64], n: usize) -> Vec<BigUint> {
        assert_eq!(lanes.len(), self.k() * n, "lanes must be k×n channel-major");
        if self.fixed_ok {
            (0..n)
                .map(|j| BigUint::from_limbs(self.fixed_accumulate(|c| lanes[c * n + j]).to_vec()))
                .collect()
        } else {
            (0..n)
                .map(|j| self.reconstruct_slow(&self.gather(lanes, n, j)))
                .collect()
        }
    }

    /// Batched signed reconstruction over channel-major lanes (see
    /// [`CrtContext::reconstruct_batch`]); one `(negative, magnitude)`
    /// pair per output.
    pub fn reconstruct_signed_batch(&self, lanes: &[u64], n: usize) -> Vec<(bool, BigUint)> {
        assert_eq!(lanes.len(), self.k() * n, "lanes must be k×n channel-major");
        self.reconstruct_signed_batch_with(n, |c, j| lanes[c * n + j])
    }

    /// Batched signed reconstruction with a caller-supplied residue read
    /// `read(channel, elem)` — the zero-copy form for residue blocks that
    /// are not `u64` lanes (e.g. the coordinator's `i64` PJRT tensors).
    pub fn reconstruct_signed_batch_with<F>(&self, n: usize, mut read: F) -> Vec<(bool, BigUint)>
    where
        F: FnMut(usize, usize) -> u64,
    {
        if self.fixed_ok {
            (0..n)
                .map(|j| self.signed_from_fixed(self.fixed_accumulate(|c| read(c, j))))
                .collect()
        } else {
            (0..n)
                .map(|j| {
                    let rv = ResidueVec {
                        r: (0..self.k()).map(|c| read(c, j)).collect(),
                    };
                    self.signed_from_big(self.reconstruct_slow(&rv))
                })
                .collect()
        }
    }

    /// Gather output `j` of a channel-major lane block (slow path only).
    fn gather(&self, lanes: &[u64], n: usize, j: usize) -> ResidueVec {
        ResidueVec {
            r: (0..self.k()).map(|c| lanes[c * n + j]).collect(),
        }
    }

    /// Mixed-radix digits (d_0..d_{k-1}) with
    /// `N = d_0 + d_1·m_0 + d_2·m_0·m_1 + …` — enables magnitude comparison
    /// without full CRT (paper §II-D / [20]).
    pub fn mixed_radix(&self, r: &ResidueVec) -> Vec<u64> {
        let k = self.k();
        let mut x: Vec<u64> = r.r.clone();
        let mut digits = vec![0u64; k];
        for j in 0..k {
            digits[j] = x[j];
            // Propagate: x_i := (x_i - d_j) * m_j^{-1} mod m_i for i > j.
            for i in (j + 1)..k {
                let b = &self.barrett[i];
                let dj = digits[j] % self.moduli[i];
                let diff = b.sub(x[i], dj);
                x[i] = b.mul(diff, self.mrc_inv[i][j]);
            }
        }
        digits
    }

    /// Compare two residue vectors by magnitude via mixed-radix digits
    /// (most-significant digit last).
    pub fn compare(&self, a: &ResidueVec, b: &ResidueVec) -> std::cmp::Ordering {
        let da = self.mixed_radix(a);
        let db = self.mixed_radix(b);
        for (x, y) in da.iter().zip(&db).rev() {
            match x.cmp(y) {
                std::cmp::Ordering::Equal => continue,
                o => return o,
            }
        }
        std::cmp::Ordering::Equal
    }

    /// Re-encode a big integer into residues (normalization engine step iv).
    pub fn encode(&self, n: &BigUint) -> ResidueVec {
        ResidueVec::encode_big(n, &self.moduli)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::DEFAULT_MODULI;
    use crate::util::proptest::{check, check_with};

    fn ctx() -> CrtContext {
        CrtContext::new(&DEFAULT_MODULI)
    }

    #[test]
    fn inv_mod_known() {
        assert_eq!(inv_mod(3, 7), 5); // 3*5=15≡1
        assert_eq!(inv_mod(1, 97), 1);
        for a in 1..97u64 {
            assert_eq!(a * inv_mod(a, 97) % 97, 1);
        }
    }

    #[test]
    #[should_panic(expected = "not invertible")]
    fn inv_mod_non_coprime_panics() {
        inv_mod(6, 9);
    }

    #[test]
    fn reconstruct_small_values() {
        let c = ctx();
        for n in [0u64, 1, 2, 65520, 65521, 1_000_000_007] {
            let r = ResidueVec::encode_u64(n, &DEFAULT_MODULI);
            assert_eq!(c.reconstruct(&r).to_u64(), Some(n), "n={n}");
        }
    }

    #[test]
    fn reconstruct_three_moduli_exhaustive() {
        let c = CrtContext::new(&[3, 5, 7]);
        for n in 0..105u64 {
            let r = ResidueVec::encode_u64(n, &[3, 5, 7]);
            assert_eq!(c.reconstruct(&r).to_u64(), Some(n));
        }
    }

    #[test]
    fn prop_crt_roundtrip_u128() {
        let c = ctx();
        check("crt-roundtrip", |rng| {
            let n = ((rng.next_u64() as u128) << 60) | rng.next_u64() as u128;
            let big = BigUint::from_u128(n);
            let r = c.encode(&big);
            crate::prop_assert!(
                c.reconstruct(&r) == big,
                "roundtrip failed n={n}"
            );
            Ok(())
        });
    }

    #[test]
    fn signed_reconstruction() {
        let c = ctx();
        // Encode -5 as M - 5.
        let m_minus_5 = c.big_m.sub(&BigUint::from_u64(5));
        let r = c.encode(&m_minus_5);
        let (neg, mag) = c.reconstruct_signed(&r);
        assert!(neg);
        assert_eq!(mag.to_u64(), Some(5));
        let (neg, mag) = c.reconstruct_signed(&c.encode(&BigUint::from_u64(5)));
        assert!(!neg);
        assert_eq!(mag.to_u64(), Some(5));
    }

    #[test]
    fn mixed_radix_reconstructs() {
        let c = CrtContext::new(&[3, 5, 7, 11]);
        for n in [0u64, 1, 104, 1000, 1154] {
            let r = ResidueVec::encode_u64(n, &[3, 5, 7, 11]);
            let d = c.mixed_radix(&r);
            // N = d0 + d1*3 + d2*15 + d3*105
            let got = d[0] + d[1] * 3 + d[2] * 15 + d[3] * 105;
            assert_eq!(got, n, "n={n} digits={d:?}");
        }
    }

    #[test]
    fn prop_mixed_radix_comparison_matches_crt() {
        let c = ctx();
        check_with("mrc-compare", 128, |rng| {
            let a128 = ((rng.next_u64() as u128) << 50) | rng.next_u64() as u128;
            let b128 = ((rng.next_u64() as u128) << 50) | rng.next_u64() as u128;
            let ra = c.encode(&BigUint::from_u128(a128));
            let rb = c.encode(&BigUint::from_u128(b128));
            crate::prop_assert!(
                c.compare(&ra, &rb) == a128.cmp(&b128),
                "compare mismatch a={a128} b={b128}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_fixed_reconstruction_matches_slow_path() {
        let c = ctx();
        assert!(c.fixed_ok, "default set must take the fast path");
        check("crt-fast-vs-slow", |rng| {
            let n = ((rng.next_u64() as u128) << 63) | rng.next_u64() as u128;
            let r = c.encode(&BigUint::from_u128(n));
            let fast = c.reconstruct(&r);
            let slow = c.reconstruct_slow(&r);
            crate::prop_assert!(fast == slow, "fast != slow for n={n}");
            Ok(())
        });
    }

    #[test]
    fn fixed_reconstruction_extremes() {
        let c = ctx();
        // All residues at m-1 (the largest representable pre-reduction sum).
        let r = ResidueVec {
            r: c.moduli.iter().map(|&m| m - 1).collect(),
        };
        assert_eq!(c.reconstruct(&r), c.reconstruct_slow(&r));
        // Zero.
        let z = ResidueVec::zero(c.k());
        assert!(c.reconstruct(&z).is_zero());
        // M - 1.
        let m1 = c.big_m.sub(&BigUint::one());
        let r = c.encode(&m1);
        assert_eq!(c.reconstruct(&r), m1);
    }

    #[test]
    fn signed_boundary_at_half_m() {
        // The M-complement sign convention splits [0, M) at M/2: values
        // below M/2 are non-negative, values at/above it are negative.
        let c = ctx();
        let half = c.big_m.shr(1);
        // M/2 - 1: the largest positive value.
        let below = half.sub(&BigUint::one());
        let (neg, mag) = c.reconstruct_signed(&c.encode(&below));
        assert!(!neg, "M/2 - 1 must be non-negative");
        assert_eq!(mag, below);
        // M/2 exactly: first negative value, magnitude M - M/2.
        let (neg, mag) = c.reconstruct_signed(&c.encode(&half));
        assert!(neg, "M/2 must be negative");
        assert_eq!(mag, c.big_m.sub(&half));
        // M/2 + 1.
        let above = half.add(&BigUint::one());
        let (neg, mag) = c.reconstruct_signed(&c.encode(&above));
        assert!(neg);
        assert_eq!(mag, c.big_m.sub(&above));
    }

    #[test]
    fn prop_signed_roundtrip_both_signs() {
        // Random magnitudes below M/2 must round-trip exactly through the
        // M-complement encoding in both signs.
        let c = ctx();
        check_with("crt-signed-roundtrip", 128, |rng| {
            // Force nonzero: 0 has no negative encoding (M - 0 wraps to 0).
            let n = (((rng.next_u64() as u128) << 58) | rng.next_u64() as u128) | 1;
            let mag = BigUint::from_u128(n);
            // Positive.
            let (neg, back) = c.reconstruct_signed(&c.encode(&mag));
            crate::prop_assert!(!neg && back == mag, "positive roundtrip n={n}");
            // Negative: encode as M - n.
            let enc = c.big_m.sub(&mag);
            let (neg, back) = c.reconstruct_signed(&c.encode(&enc));
            crate::prop_assert!(neg && back == mag, "negative roundtrip n={n}");
            Ok(())
        });
    }

    #[test]
    fn prop_batch_reconstruction_matches_per_element() {
        // reconstruct_batch / reconstruct_signed_batch over a channel-major
        // block must be bit-identical to per-element reconstruct /
        // reconstruct_signed — including all-zero outputs, sign-boundary
        // values and worst-case residues.
        let c = ctx();
        let k = c.k();
        check_with("crt-batch-vs-scalar", 64, |rng| {
            let n = rng.below(17) as usize; // includes n = 0
            let mut lanes = vec![0u64; k * n];
            for j in 0..n {
                // Mix: zero, small, random-signed-range, worst-case m-1.
                match rng.below(4) {
                    0 => {}
                    1 => {
                        for (ch, &m) in c.moduli.iter().enumerate() {
                            lanes[ch * n + j] = rng.below(m);
                        }
                    }
                    2 => {
                        for (ch, &m) in c.moduli.iter().enumerate() {
                            lanes[ch * n + j] = m - 1;
                        }
                    }
                    _ => {
                        let v = rng.next_u64();
                        for (ch, &m) in c.moduli.iter().enumerate() {
                            lanes[ch * n + j] = v % m;
                        }
                    }
                }
            }
            let batch = c.reconstruct_batch(&lanes, n);
            let signed = c.reconstruct_signed_batch(&lanes, n);
            crate::prop_assert!(batch.len() == n && signed.len() == n, "lengths");
            for j in 0..n {
                let rv = ResidueVec {
                    r: (0..k).map(|ch| lanes[ch * n + j]).collect(),
                };
                crate::prop_assert!(batch[j] == c.reconstruct(&rv), "batch j={j}");
                crate::prop_assert!(
                    signed[j] == c.reconstruct_signed(&rv),
                    "signed batch j={j}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn batch_with_reader_matches_lane_batch() {
        let c = ctx();
        let k = c.k();
        let n = 9;
        let mut lanes = vec![0u64; k * n];
        for (i, v) in lanes.iter_mut().enumerate() {
            *v = (i as u64 * 2654435761) % c.moduli[i / n];
        }
        let via_lanes = c.reconstruct_signed_batch(&lanes, n);
        let via_reader = c.reconstruct_signed_batch_with(n, |ch, j| lanes[ch * n + j]);
        assert_eq!(via_lanes.len(), via_reader.len());
        for (a, b) in via_lanes.iter().zip(&via_reader) {
            assert_eq!(a, b);
        }
    }

    #[test]
    #[should_panic(expected = "channel-major")]
    fn batch_rejects_misshaped_lanes() {
        let c = ctx();
        c.reconstruct_batch(&[0u64; 7], 2);
    }

    #[test]
    fn homomorphism_through_reconstruction() {
        // CRT(rX ⊙ rY) == CRT(rX)*CRT(rY) for products < M (Theorem 1 core).
        let c = ctx();
        let a = 0xdead_beef_u64;
        let b = 0xcafe_babe_u64;
        let ra = ResidueVec::encode_u64(a, &DEFAULT_MODULI);
        let rb = ResidueVec::encode_u64(b, &DEFAULT_MODULI);
        let rz = ra.mul(&rb, &c.barrett);
        assert_eq!(
            c.reconstruct(&rz).to_u128(),
            Some(a as u128 * b as u128)
        );
    }
}
