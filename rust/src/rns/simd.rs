//! AVX2 lane kernels (`simd` feature, x86_64 only).
//!
//! Vector implementations of the hot [`super::plane`] lane kernels,
//! reached exclusively through the runtime-dispatch shims in that module
//! (`is_x86_feature_detected!("avx2")`, cached in an atomic) — one binary
//! serves any host, falling back to the scalar kernels on CPUs without
//! AVX2.
//!
//! ## Exactness argument (why SIMD is bit-identical to scalar)
//!
//! Every kernel here computes the *same mathematical value* the scalar
//! kernel computes, so bit-identity is structural, not accidental:
//!
//! * Residues obey the 31-bit lane invariant
//!   ([`crate::rns::moduli::MAX_LANE_MODULUS_BITS`]), so
//!   `_mm256_mul_epu32` — a 32×32→64 multiply of the low halves of each
//!   64-bit lane — forms the raw ≤ 62-bit product **exactly**.
//! * AVX2 has no 64×64 mul-hi, so [`Barrett::reduce`]'s quotient estimate
//!   `q = ⌊x·mu/2^64⌋` is reassembled from four 32×32 limb products with
//!   explicit carry propagation; the result is the exact high word, hence
//!   the exact same `q`, remainder and conditional subtract as scalar.
//! * The deferred dot kernels accumulate raw products split into low/high
//!   32-bit halves (`slo`, `shi` per SIMD lane: each sums < 2^32 values
//!   at most `fold ≤ 2^32` times over 4 lanes, staying far below `u64`
//!   wrap), and the chunk total is recombined in `u128`. A fold chunk's
//!   sum of products is an exact integer below 2^94, so *any* association
//!   order gives the same total — the SIMD kernels only re-associate
//!   within a chunk and keep the scalar fold-chunk boundaries, then fold
//!   through the same `Barrett::reduce_u128`.
//!
//! The `rns::plane` property suite pins every (scalar, SIMD) pair,
//! including fold-boundary straddles and the ≥ 32-bit-modulus fallback.

use super::barrett::Barrett;
use super::plane::DOT_FOLD_TERMS;
use core::arch::x86_64::*;
use std::sync::atomic::{AtomicU8, Ordering};

/// Cached CPUID probe: 0 = unknown, 1 = AVX2 present, 2 = absent.
static AVX2_STATE: AtomicU8 = AtomicU8::new(0);

/// True iff the host CPU supports AVX2 (probed once, then cached — the
/// dispatch shims call this on every kernel invocation).
#[inline]
pub(crate) fn avx2_available() -> bool {
    match AVX2_STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let ok = is_x86_feature_detected!("avx2");
            AVX2_STATE.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
            ok
        }
    }
}

/// Low-32-bit lane mask as an `i64` broadcast seed.
const LO32: i64 = 0xffff_ffff;

/// Per-modulus constants broadcast across the four 64-bit SIMD lanes.
struct BarrettVec {
    /// Modulus in every lane.
    m: __m256i,
    /// Low 32 bits of `mu = ⌊2^64/m⌋` in every lane.
    mu0: __m256i,
    /// High 32 bits of `mu` in every lane.
    mu1: __m256i,
    /// `0xffff_ffff` in every lane.
    lo: __m256i,
}

/// Broadcast one [`Barrett`]'s constants.
///
/// # Safety
/// Requires AVX2 (guaranteed by the dispatch shims).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn barrett_vec(bar: Barrett) -> BarrettVec {
    let mu = bar.mu();
    BarrettVec {
        m: _mm256_set1_epi64x(bar.m as i64),
        mu0: _mm256_set1_epi64x((mu & 0xffff_ffff) as i64),
        mu1: _mm256_set1_epi64x((mu >> 32) as i64),
        lo: _mm256_set1_epi64x(LO32),
    }
}

/// Unaligned 4-lane load from the head of `p` (caller guarantees
/// `p.len() >= 4`).
///
/// # Safety
/// Requires AVX2 and `p.len() >= 4`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn loadu(p: &[u64]) -> __m256i {
    debug_assert!(p.len() >= 4);
    _mm256_loadu_si256(p.as_ptr() as *const __m256i)
}

/// Unaligned 4-lane store to the head of `p` (caller guarantees
/// `p.len() >= 4`).
///
/// # Safety
/// Requires AVX2 and `p.len() >= 4`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn storeu(p: &mut [u64], v: __m256i) {
    debug_assert!(p.len() >= 4);
    _mm256_storeu_si256(p.as_mut_ptr() as *mut __m256i, v);
}

/// One conditional subtract: `r - m` where `r >= m`, else `r`. Both
/// inputs are < 2^32, so the signed 64-bit compare is exact.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn csub(r: __m256i, m: __m256i) -> __m256i {
    // keep = all-ones where m > r (lane already reduced).
    let keep = _mm256_cmpgt_epi64(m, r);
    _mm256_sub_epi64(r, _mm256_andnot_si256(keep, m))
}

/// Exact `v mod m` for four lanes of `v < 2^63` — the vector form of
/// [`Barrett::reduce`]. The 64×64 mul-hi `⌊v·mu/2^64⌋` is reassembled
/// from 32×32 limb products: with `v = v1·2^32 + v0` and
/// `mu = mu1·2^32 + mu0`,
///
/// ```text
/// ⌊v·mu/2^64⌋ = v1·mu1 + (v0·mu1)»32 + (v1·mu0)»32
///             + ((v0·mu0)»32 + (v0·mu1 & LO) + (v1·mu0 & LO)) » 32
/// ```
///
/// (the last term is the carry out of the middle column; each partial sum
/// stays below 3·2^32, and `v1·mu1 < 2^63`, so nothing wraps). The
/// remainder `v − q·m` needs only the low 64 bits of `q·m`, which for
/// `m < 2^31` is `(q & LO)·m + (((q»32)·m) « 32)` with the shift
/// discarding high bits exactly as the scalar `wrapping_mul` does. One
/// conditional subtract finishes, per the `r < 2m` bound in
/// `rns::barrett`'s module docs.
///
/// # Safety
/// Requires AVX2; every lane of `v` must be below 2^63 and `bv` must be
/// the broadcast constants of a modulus satisfying the 31-bit invariant.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn reduce63_x4(v: __m256i, bv: &BarrettVec) -> __m256i {
    let v0 = _mm256_and_si256(v, bv.lo);
    let v1 = _mm256_srli_epi64::<32>(v);
    let lolo = _mm256_mul_epu32(v0, bv.mu0);
    let lohi = _mm256_mul_epu32(v0, bv.mu1);
    let hilo = _mm256_mul_epu32(v1, bv.mu0);
    let hihi = _mm256_mul_epu32(v1, bv.mu1);
    let carry = _mm256_srli_epi64::<32>(_mm256_add_epi64(
        _mm256_srli_epi64::<32>(lolo),
        _mm256_add_epi64(
            _mm256_and_si256(lohi, bv.lo),
            _mm256_and_si256(hilo, bv.lo),
        ),
    ));
    let q = _mm256_add_epi64(
        _mm256_add_epi64(hihi, carry),
        _mm256_add_epi64(
            _mm256_srli_epi64::<32>(lohi),
            _mm256_srli_epi64::<32>(hilo),
        ),
    );
    let qm = _mm256_add_epi64(
        _mm256_mul_epu32(q, bv.m),
        _mm256_slli_epi64::<32>(_mm256_mul_epu32(_mm256_srli_epi64::<32>(q), bv.m)),
    );
    csub(_mm256_sub_epi64(v, qm), bv.m)
}

/// Sum a vector's four `u64` lanes into a `u128`.
///
/// # Safety
/// Requires AVX2.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn horizontal_u128(v: __m256i) -> u128 {
    let mut t = [0u64; 4];
    _mm256_storeu_si256(t.as_mut_ptr() as *mut __m256i, v);
    t.iter().map(|&w| w as u128).sum()
}

/// AVX2 [`super::plane::lane_mul`]: four residue products and four full
/// Barrett reductions per iteration, scalar tail.
///
/// # Safety
/// Requires AVX2 at runtime and `bar.deferred_ok()` (checked by the
/// dispatch shim).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn lane_mul_avx2(bar: Barrett, x: &[u64], y: &[u64], out: &mut [u64]) {
    debug_assert!(bar.deferred_ok());
    let n = out.len().min(x.len()).min(y.len());
    let bv = barrett_vec(bar);
    let mut i = 0;
    while i + 4 <= n {
        let p = _mm256_mul_epu32(loadu(&x[i..]), loadu(&y[i..]));
        let r = reduce63_x4(p, &bv);
        storeu(&mut out[i..], r);
        i += 4;
    }
    while i < n {
        out[i] = bar.mul(x[i], y[i]);
        i += 1;
    }
}

/// AVX2 [`super::plane::lane_scale`]: the Shoup quotient
/// `q = ⌊a·shoup/2^64⌋` collapses to two 32×32 products because `a < 2^31`
/// fits one limb; remainder and conditional subtract as in scalar
/// `mul_shoup`.
///
/// # Safety
/// Requires AVX2 at runtime, `bar.deferred_ok()` and `mult < bar.m`
/// (checked by the dispatch shim / debug asserts).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn lane_scale_avx2(bar: Barrett, x: &[u64], mult: u64, out: &mut [u64]) {
    debug_assert!(bar.deferred_ok() && mult < bar.m);
    let shoup = bar.shoup(mult);
    let n = out.len().min(x.len());
    let s0 = _mm256_set1_epi64x((shoup & 0xffff_ffff) as i64);
    let s1 = _mm256_set1_epi64x((shoup >> 32) as i64);
    let mv = _mm256_set1_epi64x(bar.m as i64);
    let multv = _mm256_set1_epi64x(mult as i64);
    let mut i = 0;
    while i + 4 <= n {
        let a = loadu(&x[i..]);
        // q = (a·s1 + (a·s0)»32) » 32 — exact ⌊a·shoup/2^64⌋ for a < 2^32.
        let q = _mm256_srli_epi64::<32>(_mm256_add_epi64(
            _mm256_mul_epu32(a, s1),
            _mm256_srli_epi64::<32>(_mm256_mul_epu32(a, s0)),
        ));
        // q ≤ a·mult/m < m < 2^31, so both products are exact 32×32.
        let r = _mm256_sub_epi64(_mm256_mul_epu32(a, multv), _mm256_mul_epu32(q, mv));
        storeu(&mut out[i..], csub(r, mv));
        i += 4;
    }
    while i < n {
        out[i] = bar.mul_shoup(x[i], mult, shoup);
        i += 1;
    }
}

/// AVX2 [`super::plane::lane_fma`]: `acc + x·y` stays below 2^63
/// (≤ 62-bit product + ≤ 31-bit accumulator), one vector Barrett
/// reduction per element.
///
/// # Safety
/// Requires AVX2 at runtime and `bar.deferred_ok()` (checked by the
/// dispatch shim).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn lane_fma_avx2(bar: Barrett, acc: &mut [u64], x: &[u64], y: &[u64]) {
    debug_assert!(bar.deferred_ok());
    let n = acc.len().min(x.len()).min(y.len());
    let bv = barrett_vec(bar);
    let mut i = 0;
    while i + 4 <= n {
        let p = _mm256_mul_epu32(loadu(&x[i..]), loadu(&y[i..]));
        let v = _mm256_add_epi64(loadu(&acc[i..]), p);
        let r = reduce63_x4(v, &bv);
        storeu(&mut acc[i..], r);
        i += 4;
    }
    while i < n {
        acc[i] = bar.reduce(acc[i] + x[i] * y[i]);
        i += 1;
    }
}

/// AVX2 [`super::plane::lane_dot_folded`]: raw ≤ 62-bit products split
/// into low/high 32-bit halves and summed per SIMD lane, recombined to
/// the exact `u128` chunk total, folded through the same
/// [`Barrett::reduce_u128`] at the same chunk boundaries as scalar.
///
/// # Safety
/// Requires AVX2 at runtime and `bar.deferred_ok()` (checked by the
/// dispatch shim).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn lane_dot_folded_avx2(bar: Barrett, x: &[u64], y: &[u64], fold: usize) -> u64 {
    debug_assert!(bar.deferred_ok());
    let n = x.len().min(y.len());
    let (x, y) = (&x[..n], &y[..n]);
    let fold = fold.clamp(1, DOT_FOLD_TERMS);
    let lo = _mm256_set1_epi64x(LO32);
    let mut acc = 0u64;
    for (xc, yc) in x.chunks(fold).zip(y.chunks(fold)) {
        let mut slo = _mm256_setzero_si256();
        let mut shi = _mm256_setzero_si256();
        let mut i = 0;
        while i + 4 <= xc.len() {
            let p = _mm256_mul_epu32(loadu(&xc[i..]), loadu(&yc[i..]));
            // Per lane: ≤ fold/4 ≤ 2^30 additions of < 2^32 (slo) and
            // < 2^30 (shi) values — both far below u64 wrap.
            slo = _mm256_add_epi64(slo, _mm256_and_si256(p, lo));
            shi = _mm256_add_epi64(shi, _mm256_srli_epi64::<32>(p));
            i += 4;
        }
        let mut total = horizontal_u128(slo) + (horizontal_u128(shi) << 32);
        while i < xc.len() {
            total += (xc[i] * yc[i]) as u128;
            i += 1;
        }
        acc = bar.add(acc, bar.reduce_u128(total));
    }
    acc
}

/// AVX2 column gather for
/// [`super::plane::ResiduePlane::gather_columns`]: four `usize` column
/// indices load as one vector of `i64` lanes (same 8-byte layout on
/// x86_64) and drive one hardware `vpgatherqq` per iteration, scalar
/// tail. Pure `u64` movement — no modulus involved, so there is no
/// `deferred_ok` gate.
///
/// # Safety
/// Requires AVX2 at runtime and every `idx[t] < src.len()` (the
/// dispatch shim verifies both; an out-of-range index would make the
/// hardware gather read out of bounds).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gather_lane_avx2(src: &[u64], idx: &[usize], out: &mut [u64]) {
    let n = idx.len().min(out.len());
    debug_assert!(idx[..n].iter().all(|&j| j < src.len()));
    let base = src.as_ptr() as *const i64;
    let mut i = 0;
    while i + 4 <= n {
        let vindex = _mm256_loadu_si256(idx[i..].as_ptr() as *const __m256i);
        let v = _mm256_i64gather_epi64::<8>(base, vindex);
        storeu(&mut out[i..], v);
        i += 4;
    }
    while i < n {
        out[i] = src[idx[i]];
        i += 1;
    }
}

/// AVX2 column scatter for
/// [`super::plane::ResiduePlane::scatter_columns`]: AVX2 has no scatter
/// instruction, so this streams the dense source four lanes at a time
/// through one vector load + register spill and finishes with scalar
/// indexed stores — the unrolled form keeps the source traffic vectorized
/// while the stores stay in index order (duplicate indices resolve
/// last-write-wins exactly as the scalar kernel).
///
/// # Safety
/// Requires AVX2 at runtime and every `idx[t] < dst.len()` (indexed
/// stores are bounds-checked slices, so a bad index panics rather than
/// corrupting memory — the shim still pre-verifies to keep the paths
/// identical).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn scatter_lane_avx2(dst: &mut [u64], idx: &[usize], src: &[u64]) {
    let n = idx.len().min(src.len());
    let mut t = [0u64; 4];
    let mut i = 0;
    while i + 4 <= n {
        _mm256_storeu_si256(t.as_mut_ptr() as *mut __m256i, loadu(&src[i..]));
        dst[idx[i]] = t[0];
        dst[idx[i + 1]] = t[1];
        dst[idx[i + 2]] = t[2];
        dst[idx[i + 3]] = t[3];
        i += 4;
    }
    while i < n {
        dst[idx[i]] = src[i];
        i += 1;
    }
}

/// AVX2 [`super::plane::lane_dot_scaled`]: vector Barrett brings each
/// product under `m`, the third factor multiplies in exactly
/// (`r, s < 2^31`), and the ≤ 62-bit terms accumulate through the same
/// split-halves scheme as [`lane_dot_folded_avx2`].
///
/// # Safety
/// Requires AVX2 at runtime and `bar.deferred_ok()` (checked by the
/// dispatch shim).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn lane_dot_scaled_avx2(
    bar: Barrett,
    x: &[u64],
    y: &[u64],
    mults: &[u64],
) -> u64 {
    debug_assert!(bar.deferred_ok());
    let n = x.len().min(y.len()).min(mults.len());
    let (x, y, mults) = (&x[..n], &y[..n], &mults[..n]);
    let bv = barrett_vec(bar);
    let mut acc = 0u64;
    for ((xc, yc), sc) in x
        .chunks(DOT_FOLD_TERMS)
        .zip(y.chunks(DOT_FOLD_TERMS))
        .zip(mults.chunks(DOT_FOLD_TERMS))
    {
        let mut slo = _mm256_setzero_si256();
        let mut shi = _mm256_setzero_si256();
        let mut i = 0;
        while i + 4 <= xc.len() {
            let p = _mm256_mul_epu32(loadu(&xc[i..]), loadu(&yc[i..]));
            let r = reduce63_x4(p, &bv);
            let t = _mm256_mul_epu32(r, loadu(&sc[i..]));
            slo = _mm256_add_epi64(slo, _mm256_and_si256(t, bv.lo));
            shi = _mm256_add_epi64(shi, _mm256_srli_epi64::<32>(t));
            i += 4;
        }
        let mut sum = horizontal_u128(slo) + (horizontal_u128(shi) << 32);
        while i < xc.len() {
            sum += (bar.reduce(xc[i] * yc[i]) * sc[i]) as u128;
            i += 1;
        }
        acc = bar.add(acc, bar.reduce_u128(sum));
    }
    acc
}
