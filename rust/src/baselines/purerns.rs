//! Pure residue number system baseline (paper §II-D, §VIII-C).
//!
//! Residues over the same modulus set as HRFNA but with **no exponent**:
//! reals are committed to one global fixed scale `2^{-frac_bits}`. The two
//! classic failure modes follow directly:
//!
//! 1. Every multiplication doubles the scale, so pure RNS must rescale by
//!    `2^{frac_bits}` via full CRT reconstruction *per multiplication* —
//!    the reconstruction cost HRFNA's exponent eliminates (counted here).
//! 2. There is no headroom management: when magnitudes exceed M/2 the
//!    value silently wraps (counted, and visible as garbage downstream) —
//!    the "no dynamic range / no stability" rows of Tables I and IV.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::bigint::BigUint;
use crate::rns::{CrtContext, ResidueVec};
use crate::workloads::traits::Numeric;

/// Context: CRT state, the fixed global scale, failure telemetry.
#[derive(Debug)]
pub struct PureRnsContext {
    pub crt: CrtContext,
    /// Global fixed fractional scale: value = N · 2^{-frac_bits}.
    pub frac_bits: u32,
    /// Full CRT reconstructions forced by rescaling.
    pub rescale_reconstructions: AtomicU64,
    /// Detected range overflows (best-effort: detected at encode/decode).
    pub overflows: AtomicU64,
}

impl PureRnsContext {
    /// Same default moduli as HRFNA; 24 fractional bits.
    pub fn paper_default() -> PureRnsContext {
        PureRnsContext {
            crt: CrtContext::new(&crate::rns::moduli::default_moduli()),
            frac_bits: 24,
            rescale_reconstructions: AtomicU64::new(0),
            overflows: AtomicU64::new(0),
        }
    }

    fn half_m(&self) -> BigUint {
        self.crt.big_m.shr(1)
    }

    /// Reconstructions performed so far for rescaling.
    pub fn reconstruction_count(&self) -> u64 {
        self.rescale_reconstructions.load(Ordering::Relaxed)
    }
}

/// A pure-RNS value: residues of the M-complement signed integer
/// `N = round(x · 2^{frac_bits})`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PureRns {
    pub r: ResidueVec,
}

fn negate(r: &ResidueVec, ctx: &PureRnsContext) -> ResidueVec {
    ResidueVec {
        r: r.r
            .iter()
            .zip(&ctx.crt.moduli)
            .map(|(&ri, &mi)| if ri == 0 { 0 } else { mi - ri })
            .collect(),
    }
}

impl Numeric for PureRns {
    type Ctx = PureRnsContext;

    fn name() -> &'static str {
        "PureRNS"
    }

    fn from_f64(x: f64, ctx: &PureRnsContext) -> PureRns {
        let scaled = x * crate::hybrid::number::pow2(ctx.frac_bits as i32);
        // Pure RNS has no exponent: out-of-range values simply alias.
        let mag = scaled.abs().round();
        if !mag.is_finite() || BigUint::from_u128(mag.min(3.4e38) as u128) >= ctx.half_m() {
            ctx.overflows.fetch_add(1, Ordering::Relaxed);
        }
        let n = mag.min(1e30) as u128; // beyond this it is garbage anyway
        let mut r = ctx.crt.encode(&BigUint::from_u128(n));
        if x < 0.0 {
            r = negate(&r, ctx);
        }
        PureRns { r }
    }

    fn to_f64(&self, ctx: &PureRnsContext) -> f64 {
        let (neg, mag) = ctx.crt.reconstruct_signed(&self.r);
        let v = mag.to_f64() * crate::hybrid::number::pow2(-(ctx.frac_bits as i32));
        if neg {
            -v
        } else {
            v
        }
    }

    fn zero(ctx: &PureRnsContext) -> PureRns {
        PureRns {
            r: ResidueVec::zero(ctx.crt.k()),
        }
    }

    fn add(&self, o: &PureRns, ctx: &PureRnsContext) -> PureRns {
        // Carry-free — but overflow past M/2 wraps silently.
        PureRns {
            r: self.r.add(&o.r, &ctx.crt.barrett),
        }
    }

    fn sub(&self, o: &PureRns, ctx: &PureRnsContext) -> PureRns {
        PureRns {
            r: self.r.sub(&o.r, &ctx.crt.barrett),
        }
    }

    fn mul(&self, o: &PureRns, ctx: &PureRnsContext) -> PureRns {
        // Residue multiply doubles the fixed scale; pure RNS must rescale
        // by 2^{frac_bits} via full reconstruction (the §II-D cost).
        let prod = PureRns {
            r: self.r.mul(&o.r, &ctx.crt.barrett),
        };
        ctx.rescale_reconstructions.fetch_add(1, Ordering::Relaxed);
        let (neg, mag) = ctx.crt.reconstruct_signed(&prod.r);
        // Round-half-up power-of-two scaling.
        let half = BigUint::one().shl(ctx.frac_bits - 1);
        let scaled = mag.add(&half).shr(ctx.frac_bits);
        if scaled >= ctx.half_m() {
            ctx.overflows.fetch_add(1, Ordering::Relaxed);
        }
        let mut r = ctx.crt.encode(&scaled);
        if neg && !scaled.is_zero() {
            r = negate(&r, ctx);
        }
        PureRns { r }
    }

    fn neg(&self, ctx: &PureRnsContext) -> PureRns {
        PureRns {
            r: negate(&self.r, ctx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_moderate_values() {
        let c = PureRnsContext::paper_default();
        for x in [0.0, 1.0, -2.5, 1000.123, -65536.25] {
            let v = PureRns::from_f64(x, &c);
            assert!((v.to_f64(&c) - x).abs() < 2f64.powi(-23), "x={x}");
        }
    }

    #[test]
    fn mul_rescales_through_crt() {
        let c = PureRnsContext::paper_default();
        let a = PureRns::from_f64(3.5, &c);
        let b = PureRns::from_f64(-2.0, &c);
        let before = c.reconstruction_count();
        let p = a.mul(&b, &c);
        assert!((p.to_f64(&c) + 7.0).abs() < 1e-5);
        assert_eq!(c.reconstruction_count(), before + 1, "mul must reconstruct");
    }

    #[test]
    fn add_is_carry_free_and_correct_in_range() {
        let c = PureRnsContext::paper_default();
        let a = PureRns::from_f64(1.25, &c);
        let b = PureRns::from_f64(2.5, &c);
        assert!((a.add(&b, &c).to_f64(&c) - 3.75).abs() < 1e-6);
        assert!((a.sub(&b, &c).to_f64(&c) + 1.25).abs() < 1e-6);
    }

    #[test]
    fn overflow_detection_fires_for_huge_values() {
        let c = PureRnsContext::paper_default();
        let _ = PureRns::from_f64(1e38, &c);
        assert!(c.overflows.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn repeated_squaring_wraps_silently_into_garbage() {
        // The §VIII-C instability story: no exponent, so magnitude growth
        // is unmanaged — (2^20)^(2^k) escapes M silently and the value is
        // garbage with no error signal on the arithmetic path.
        let c = PureRnsContext::paper_default();
        let mut v = PureRns::from_f64(1048576.0, &c);
        let mut truth = 1048576.0f64;
        for _ in 0..4 {
            v = v.mul(&v.clone(), &c);
            truth *= truth;
        }
        let got = v.to_f64(&c);
        // truth = 2^320, far beyond M·2^-24 ≈ 2^104: the result must be wrong.
        let rel = ((got - truth) / truth).abs();
        assert!(rel > 0.99, "pure RNS should have wrapped: got={got} truth={truth}");
    }
}
