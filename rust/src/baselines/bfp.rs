//! Block floating-point baseline (paper §II-E, §VIII-B).
//!
//! Model: mantissas carry `mant_bits` bits and addition aligns to the
//! larger exponent with *truncating* right shifts — the cheap datapath a
//! BFP FPGA core uses. When an accumulator's exponent grows, every addend
//! is quantized at the accumulator's scale, so long accumulation chains
//! lose low-order bits monotonically: exactly the error-growth-with-N and
//! long-horizon drift the paper reports for BFP (§VII-B/D).

use crate::workloads::traits::Numeric;

/// BFP configuration: mantissa width in bits (shared-exponent blocks in
/// FPGA BFP pipelines typically carry 12–18-bit mantissas; default 16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BfpConfig {
    pub mant_bits: u32,
}

impl Default for BfpConfig {
    fn default() -> Self {
        BfpConfig { mant_bits: 16 }
    }
}

/// A block-floating value: `value = mant · 2^exp`, |mant| < 2^mant_bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bfp {
    pub mant: i64,
    pub exp: i32,
}

/// Right shift with round-half-away-from-zero (the rounding a fair BFP
/// core applies when aligning mantissas; pure truncation would add a
/// systematic bias that makes the baseline a strawman).
#[inline]
fn rshift_round(v: i128, s: u32) -> i128 {
    if s == 0 {
        return v;
    }
    if s >= 127 {
        return 0;
    }
    let half = 1i128 << (s - 1);
    if v >= 0 {
        (v + half) >> s
    } else {
        -((-v + half) >> s)
    }
}

impl Bfp {
    /// Requantize so |mant| fits in `mant_bits` (rounded shift).
    fn renorm(mant: i128, exp: i32, cfg: &BfpConfig) -> Bfp {
        let limit = 1i128 << cfg.mant_bits;
        let mut shift = 0u32;
        while rshift_round(mant, shift).abs() >= limit {
            shift += 1;
        }
        let m = rshift_round(mant, shift);
        if m == 0 {
            return Bfp { mant: 0, exp: 0 };
        }
        Bfp {
            mant: m as i64,
            exp: exp + shift as i32,
        }
    }
}

impl Numeric for Bfp {
    type Ctx = BfpConfig;

    fn name() -> &'static str {
        "BFP"
    }

    fn from_f64(x: f64, cfg: &BfpConfig) -> Bfp {
        if x == 0.0 || !x.is_finite() {
            return Bfp { mant: 0, exp: 0 };
        }
        let e = x.abs().log2().floor() as i32;
        let exp = e - cfg.mant_bits as i32 + 1;
        let mant = (x * crate::hybrid::number::pow2(-exp)).round() as i128;
        Bfp::renorm(mant, exp, cfg)
    }

    fn to_f64(&self, _cfg: &BfpConfig) -> f64 {
        self.mant as f64 * crate::hybrid::number::pow2(self.exp)
    }

    fn zero(_cfg: &BfpConfig) -> Bfp {
        Bfp { mant: 0, exp: 0 }
    }

    fn add(&self, o: &Bfp, cfg: &BfpConfig) -> Bfp {
        if self.mant == 0 {
            return *o;
        }
        if o.mant == 0 {
            return *self;
        }
        // Align to the larger exponent; the smaller operand's low bits are
        // rounded away at the shared scale (block-shared-exponent
        // behaviour: precision loss grows with magnitude divergence).
        let (hi, lo) = if self.exp >= o.exp { (self, o) } else { (o, self) };
        let delta = (hi.exp - lo.exp).min(126) as u32;
        let lo_mant = rshift_round(lo.mant as i128, delta);
        Bfp::renorm(hi.mant as i128 + lo_mant, hi.exp, cfg)
    }

    fn sub(&self, o: &Bfp, cfg: &BfpConfig) -> Bfp {
        self.add(&o.neg(cfg), cfg)
    }

    fn mul(&self, o: &Bfp, cfg: &BfpConfig) -> Bfp {
        // 2·mant_bits product rounded back into range.
        Bfp::renorm(self.mant as i128 * o.mant as i128, self.exp + o.exp, cfg)
    }

    fn neg(&self, _cfg: &BfpConfig) -> Bfp {
        Bfp {
            mant: -self.mant,
            exp: self.exp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BfpConfig {
        BfpConfig::default()
    }

    #[test]
    fn roundtrip_within_mant_precision() {
        let c = cfg();
        for x in [1.0, -3.75, 1234.5, 6.02e23, -1.6e-19] {
            let b = Bfp::from_f64(x, &c);
            let rel = ((b.to_f64(&c) - x) / x).abs();
            assert!(rel < 2f64.powi(-(c.mant_bits as i32) + 1), "x={x} rel={rel}");
        }
    }

    #[test]
    fn zero_identity() {
        let c = cfg();
        let z = Bfp::zero(&c);
        let x = Bfp::from_f64(5.5, &c);
        assert_eq!(z.add(&x, &c), x);
        assert_eq!(x.add(&z, &c), x);
        assert_eq!(x.mul(&z, &c).mant, 0);
    }

    #[test]
    fn small_addend_lost_at_large_scale() {
        // The BFP failure mode: a large accumulator absorbs small addends.
        let c = cfg();
        let big = Bfp::from_f64(1e9, &c);
        let tiny = Bfp::from_f64(1.0, &c);
        let sum = big.add(&tiny, &c);
        assert_eq!(sum, big, "BFP must drop the small addend (by design)");
    }

    #[test]
    fn accumulation_error_grows_with_n() {
        // Sum 1.0 a million times starting from 2^24: FP-like formats keep
        // ~mant_bits precision; measure drift grows.
        let c = cfg();
        let mut acc = Bfp::from_f64(16_777_216.0, &c);
        let one = Bfp::from_f64(1.0, &c);
        for _ in 0..100_000 {
            acc = acc.add(&one, &c);
        }
        let want = 16_777_216.0 + 100_000.0;
        let err = (acc.to_f64(&c) - want).abs();
        assert!(err > 1000.0, "BFP should show visible drift, err={err}");
    }

    #[test]
    fn mul_matches_f64_for_exact_mantissas() {
        let c = cfg();
        let a = Bfp::from_f64(3.0, &c);
        let b = Bfp::from_f64(-7.0, &c);
        assert_eq!(a.mul(&b, &c).to_f64(&c), -21.0);
    }

    #[test]
    fn sub_and_neg() {
        let c = cfg();
        let a = Bfp::from_f64(10.0, &c);
        let b = Bfp::from_f64(4.0, &c);
        assert_eq!(a.sub(&b, &c).to_f64(&c), 6.0);
        assert_eq!(a.neg(&c).to_f64(&c), -10.0);
    }
}
