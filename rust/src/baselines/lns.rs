//! Logarithmic number system baseline (paper §II-C).
//!
//! Values are `sign · 2^log` with `log` a fixed-point log2 magnitude
//! (`frac_bits` fractional bits). Multiplication/division are exact
//! fixed-point additions; addition/subtraction require the Gaussian
//! logarithm `log2(1 ± 2^{-d})`, which hardware realizes with tables or
//! polynomial approximation — modeled here by evaluating in f64 and
//! quantizing the result back to `frac_bits`, charging the LNS op counters.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::workloads::traits::Numeric;

/// LNS configuration: fractional bits of the log-domain fixed point.
#[derive(Debug)]
pub struct LnsConfig {
    pub frac_bits: u32,
    /// Addition/subtraction events (the expensive ops in LNS).
    pub addsub_ops: AtomicU64,
}

impl Default for LnsConfig {
    fn default() -> LnsConfig {
        LnsConfig {
            frac_bits: 23,
            addsub_ops: AtomicU64::new(0),
        }
    }
}

impl LnsConfig {
    fn quantum(&self) -> f64 {
        crate::hybrid::number::pow2(-(self.frac_bits as i32))
    }
}

/// An LNS value: `sign ∈ {-1, 0, +1}`, `log` = fixed-point log2|x|.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lns {
    pub sign: i8,
    /// log2|x| in units of 2^{-frac_bits} (ignored when sign == 0).
    pub log: i64,
}

impl Lns {
    fn log_f64(&self, cfg: &LnsConfig) -> f64 {
        self.log as f64 * cfg.quantum()
    }

    fn from_sign_log(sign: i8, log_f: f64, cfg: &LnsConfig) -> Lns {
        Lns {
            sign,
            log: (log_f / cfg.quantum()).round() as i64,
        }
    }
}

impl Numeric for Lns {
    type Ctx = LnsConfig;

    fn name() -> &'static str {
        "LNS"
    }

    fn from_f64(x: f64, cfg: &LnsConfig) -> Lns {
        if x == 0.0 || !x.is_finite() {
            return Lns { sign: 0, log: 0 };
        }
        Lns::from_sign_log(if x > 0.0 { 1 } else { -1 }, x.abs().log2(), cfg)
    }

    fn to_f64(&self, cfg: &LnsConfig) -> f64 {
        if self.sign == 0 {
            return 0.0;
        }
        self.sign as f64 * 2f64.powf(self.log_f64(cfg))
    }

    fn zero(_cfg: &LnsConfig) -> Lns {
        Lns { sign: 0, log: 0 }
    }

    fn add(&self, o: &Lns, cfg: &LnsConfig) -> Lns {
        if self.sign == 0 {
            return *o;
        }
        if o.sign == 0 {
            return *self;
        }
        cfg.addsub_ops.fetch_add(1, Ordering::Relaxed);
        // Gaussian log: ensure |a| >= |b|.
        let (a, b) = if self.log >= o.log { (self, o) } else { (o, self) };
        let d = (a.log - b.log) as f64 * cfg.quantum(); // >= 0
        if a.sign == b.sign {
            // log2(|a|+|b|) = log_a + log2(1 + 2^-d)
            let corr = (1.0 + 2f64.powf(-d)).log2();
            Lns::from_sign_log(a.sign, a.log_f64(cfg) + corr, cfg)
        } else {
            // |a| - |b|: cancellation — the LNS weak spot.
            if a.log == b.log {
                return Lns { sign: 0, log: 0 };
            }
            let corr = (1.0 - 2f64.powf(-d)).log2();
            Lns::from_sign_log(a.sign, a.log_f64(cfg) + corr, cfg)
        }
    }

    fn sub(&self, o: &Lns, cfg: &LnsConfig) -> Lns {
        self.add(&o.neg(cfg), cfg)
    }

    fn mul(&self, o: &Lns, _cfg: &LnsConfig) -> Lns {
        if self.sign == 0 || o.sign == 0 {
            return Lns { sign: 0, log: 0 };
        }
        Lns {
            sign: self.sign * o.sign,
            log: self.log + o.log, // exact in the log domain
        }
    }

    fn neg(&self, _cfg: &LnsConfig) -> Lns {
        Lns {
            sign: -self.sign,
            log: self.log,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LnsConfig {
        LnsConfig::default()
    }

    #[test]
    fn roundtrip() {
        let c = cfg();
        for x in [1.0, -2.5, 1e10, -1e-10, 3.14159] {
            let v = Lns::from_f64(x, &c);
            let rel = ((v.to_f64(&c) - x) / x).abs();
            assert!(rel < 1e-6, "x={x} rel={rel}");
        }
    }

    #[test]
    fn mul_is_cheap_and_accurate() {
        let c = cfg();
        let a = Lns::from_f64(3.0, &c);
        let b = Lns::from_f64(-4.0, &c);
        let p = a.mul(&b, &c);
        assert!(((p.to_f64(&c) + 12.0) / 12.0).abs() < 1e-6);
        assert_eq!(c.addsub_ops.load(Ordering::Relaxed), 0, "mul must not use add path");
    }

    #[test]
    fn add_counts_expensive_ops() {
        let c = cfg();
        let a = Lns::from_f64(3.0, &c);
        let b = Lns::from_f64(4.0, &c);
        let s = a.add(&b, &c);
        assert!(((s.to_f64(&c) - 7.0) / 7.0).abs() < 1e-5);
        assert_eq!(c.addsub_ops.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn opposite_sign_cancellation() {
        let c = cfg();
        let a = Lns::from_f64(5.0, &c);
        let b = Lns::from_f64(-5.0, &c);
        assert_eq!(a.add(&b, &c).sign, 0);
        let d = a.add(&Lns::from_f64(-4.999, &c), &c);
        // Near-cancellation: answer ~0.001; tolerate the LNS error blowup.
        assert!(d.to_f64(&c) > 0.0 && d.to_f64(&c) < 0.01);
    }

    #[test]
    fn zero_propagation() {
        let c = cfg();
        let z = Lns::zero(&c);
        let a = Lns::from_f64(2.0, &c);
        assert_eq!(z.mul(&a, &c).sign, 0);
        assert_eq!(z.add(&a, &c), a);
    }
}
