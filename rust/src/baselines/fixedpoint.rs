//! Fixed-point (Qm.n) baseline (paper §II-B, §VIII Tables I/IV).
//!
//! Signed two's-complement with `frac_bits` fractional bits inside a
//! `total_bits`-wide word, saturating on overflow (with a counter so
//! workloads can report how often the format failed). The paper's point:
//! excellent hardware cost, but no dynamic range — long accumulations or
//! multi-scale operands either saturate or demand conservative pre-scaling
//! that destroys precision.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::workloads::traits::Numeric;

/// Q-format configuration + saturation telemetry.
#[derive(Debug)]
pub struct FixedConfig {
    /// Total word width (≤ 63).
    pub total_bits: u32,
    /// Fractional bits.
    pub frac_bits: u32,
    /// Saturation events observed (overflow failures).
    pub saturations: AtomicU64,
}

impl FixedConfig {
    /// Q(total-frac).frac format.
    pub fn new(total_bits: u32, frac_bits: u32) -> FixedConfig {
        assert!(total_bits <= 63 && frac_bits < total_bits);
        FixedConfig {
            total_bits,
            frac_bits,
            saturations: AtomicU64::new(0),
        }
    }

    /// Common FPGA DSP-friendly default: Q16.16 in a 32-bit word.
    pub fn q16_16() -> FixedConfig {
        FixedConfig::new(32, 16)
    }

    fn max_raw(&self) -> i64 {
        (1i64 << (self.total_bits - 1)) - 1
    }

    fn saturate(&self, v: i128) -> i64 {
        let max = self.max_raw() as i128;
        if v > max {
            self.saturations.fetch_add(1, Ordering::Relaxed);
            max as i64
        } else if v < -max {
            self.saturations.fetch_add(1, Ordering::Relaxed);
            -(max as i64)
        } else {
            v as i64
        }
    }

    /// Number of saturation events so far.
    pub fn saturation_count(&self) -> u64 {
        self.saturations.load(Ordering::Relaxed)
    }
}

/// A fixed-point value: `value = raw / 2^frac_bits`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fixed {
    pub raw: i64,
}

impl Numeric for Fixed {
    type Ctx = FixedConfig;

    fn name() -> &'static str {
        "Fixed"
    }

    fn from_f64(x: f64, cfg: &FixedConfig) -> Fixed {
        let scaled = x * crate::hybrid::number::pow2(cfg.frac_bits as i32);
        if !scaled.is_finite() {
            cfg.saturations.fetch_add(1, Ordering::Relaxed);
            return Fixed {
                raw: if x > 0.0 { cfg.max_raw() } else { -cfg.max_raw() },
            };
        }
        Fixed {
            raw: cfg.saturate(scaled.round() as i128),
        }
    }

    fn to_f64(&self, cfg: &FixedConfig) -> f64 {
        self.raw as f64 * crate::hybrid::number::pow2(-(cfg.frac_bits as i32))
    }

    fn zero(_cfg: &FixedConfig) -> Fixed {
        Fixed { raw: 0 }
    }

    fn add(&self, o: &Fixed, cfg: &FixedConfig) -> Fixed {
        Fixed {
            raw: cfg.saturate(self.raw as i128 + o.raw as i128),
        }
    }

    fn sub(&self, o: &Fixed, cfg: &FixedConfig) -> Fixed {
        Fixed {
            raw: cfg.saturate(self.raw as i128 - o.raw as i128),
        }
    }

    fn mul(&self, o: &Fixed, cfg: &FixedConfig) -> Fixed {
        // (a·b) >> frac with rounding; i128 intermediate.
        let prod = self.raw as i128 * o.raw as i128;
        let half = 1i128 << (cfg.frac_bits - 1);
        Fixed {
            raw: cfg.saturate((prod + half) >> cfg.frac_bits),
        }
    }

    fn neg(&self, _cfg: &FixedConfig) -> Fixed {
        Fixed { raw: -self.raw }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_q16_16() {
        let c = FixedConfig::q16_16();
        for x in [0.0, 1.0, -1.5, 1234.0625, -32767.5] {
            let f = Fixed::from_f64(x, &c);
            assert!((f.to_f64(&c) - x).abs() <= 2f64.powi(-17), "x={x}");
        }
    }

    #[test]
    fn arithmetic_basics() {
        let c = FixedConfig::q16_16();
        let a = Fixed::from_f64(2.5, &c);
        let b = Fixed::from_f64(-1.25, &c);
        assert_eq!(a.add(&b, &c).to_f64(&c), 1.25);
        assert_eq!(a.sub(&b, &c).to_f64(&c), 3.75);
        assert_eq!(a.mul(&b, &c).to_f64(&c), -3.125);
        assert_eq!(a.neg(&c).to_f64(&c), -2.5);
    }

    #[test]
    fn saturation_on_overflow() {
        let c = FixedConfig::q16_16();
        let big = Fixed::from_f64(30000.0, &c);
        let sum = big.add(&big, &c); // 60000 > 32767.x
        assert!(c.saturation_count() > 0);
        assert!((sum.to_f64(&c) - 32768.0).abs() < 1.0);
    }

    #[test]
    fn mul_saturates_on_range_escape() {
        let c = FixedConfig::q16_16();
        let a = Fixed::from_f64(1000.0, &c);
        let before = c.saturation_count();
        let _ = a.mul(&a, &c); // 1e6 >> range
        assert!(c.saturation_count() > before);
    }

    #[test]
    fn from_f64_clamps_out_of_range() {
        let c = FixedConfig::q16_16();
        let f = Fixed::from_f64(1e20, &c);
        assert_eq!(f.raw, (1i64 << 31) - 1);
        assert!(c.saturation_count() > 0);
    }
}
