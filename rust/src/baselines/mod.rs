//! Baseline numeric formats for comparative evaluation (paper §VIII,
//! Tables I/IV): block floating-point, fixed-point, pure RNS and LNS.
//! IEEE FP32/FP64 baselines are the native `f32`/`f64` impls in
//! [`crate::workloads::traits`].
//!
//! Each baseline is implemented honestly enough to reproduce its
//! characteristic failure mode from the paper's comparison: BFP loses
//! precision when magnitudes diverge inside a block and drifts over long
//! accumulations; fixed-point saturates/overflows without conservative
//! scaling; pure RNS wraps silently past M and needs expensive CRT
//! rescaling for fractions; LNS multiplies cheaply but pays approximation
//! error on every addition.

pub mod bfp;
pub mod fixedpoint;
pub mod purerns;
pub mod lns;

pub use bfp::{Bfp, BfpConfig};
pub use fixedpoint::{Fixed, FixedConfig};
pub use purerns::{PureRns, PureRnsContext};
pub use lns::{Lns, LnsConfig};
