//! `hrfna` CLI — leader entrypoint for the HRFNA reproduction.
//!
//! Subcommands:
//!   info        platform + configuration summary (Table II)
//!   dot         dot-product accuracy/normalization experiment (§VII-B)
//!   matmul      matrix-multiplication experiment (§VII-C)
//!   rk4         long-horizon RK4 stability experiment (§VII-D)
//!   resources   iso-throughput resource + energy comparison (§VII/VIII)
//!   tables      qualitative Tables I & IV
//!   serve       start the coordinator and run a mixed request workload
//!   serve-rpc   serve the coordinator over TCP JSON-RPC (--features rpc)
//!   worker      cluster worker: serve-rpc under its cluster name (--features rpc)
//!   route       cluster router: shard jobs across --workers (--features rpc)
//!   rpc-load    drive a serve-rpc/worker/route server with socket load (--features rpc)

use hrfna::baselines::{Bfp, BfpConfig};
use hrfna::config::HrfnaConfig;
use hrfna::coordinator::{
    Backend, ContextRegistry, Coordinator, CoordinatorConfig, InProcess, JobKind, JobSpec,
    Payload, DEFAULT_WAIT,
};
use hrfna::fpga::pipeline::{model_workload, speedup, WorkloadKind};
use hrfna::fpga::report;
use hrfna::fpga::resources::FormatArch;
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::runtime::EngineHandle;
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;
use hrfna::util::table::{eng, Table};
use hrfna::workloads::{dot, generators::Dist, matmul, rk4};
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    // Fault injection is process-wide: installing here covers every
    // serving subcommand (serve, serve-rpc, worker). The call sites are
    // compiled only with `--features fault-inject`, so on a default
    // build the flag installs a plan nothing reads.
    if let Some(spec) = args.get("inject-faults") {
        use hrfna::util::faults::FaultPlan;
        match FaultPlan::parse(spec) {
            Ok(plan) => {
                hrfna::util::faults::install(plan);
                if cfg!(feature = "fault-inject") {
                    eprintln!("fault injection armed: {plan:?}");
                } else {
                    eprintln!(
                        "warning: --inject-faults set but this build lacks the \
                         fault-inject feature; no faults will fire"
                    );
                }
            }
            Err(e) => {
                eprintln!("bad --inject-faults: {e}");
                std::process::exit(2);
            }
        }
    }
    let cfg = match args.get("config") {
        Some(path) => HrfnaConfig::from_file(path).expect("config file"),
        None => HrfnaConfig::preset(&args.str_or("preset", "paper")).expect("preset"),
    };
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&cfg),
        Some("dot") => cmd_dot(&args, &cfg),
        Some("matmul") => cmd_matmul(&args, &cfg),
        Some("rk4") => cmd_rk4(&args, &cfg),
        Some("resources") => cmd_resources(&cfg),
        Some("tables") => cmd_tables(),
        Some("serve") => cmd_serve(&args, &cfg),
        // `worker` is the cluster name for the same edge serve-rpc runs:
        // an RpcServer over an in-process coordinator.
        Some("serve-rpc") => cmd_serve_rpc(&args, &cfg, "serve-rpc"),
        Some("worker") => cmd_serve_rpc(&args, &cfg, "worker"),
        Some("route") => cmd_route(&args),
        Some("rpc-load") => cmd_rpc_load(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o}");
            }
            eprintln!(
                "usage: hrfna <info|dot|matmul|rk4|resources|tables|serve|serve-rpc|worker|route|rpc-load> \
                 [--preset paper|low-precision|stress-norm|wide] [--config file.toml] ..."
            );
            std::process::exit(2);
        }
    }
}

fn cmd_info(cfg: &HrfnaConfig) {
    report::table2(cfg).print();
    match EngineHandle::spawn(None) {
        Ok(engine) => {
            let (platform, names) = engine.info().expect("engine info");
            println!("PJRT: {platform}");
            println!("artifacts: {names:?}");
            engine.shutdown();
        }
        Err(e) => println!("PJRT engine unavailable ({e}); run `make artifacts`"),
    }
}

fn cmd_dot(args: &Args, cfg: &HrfnaConfig) {
    let n = args.parse_or("n", 4096usize);
    let trials = args.parse_or("trials", 5usize);
    let seed = args.parse_or("seed", 42u64);
    let ctx = HrfnaContext::new(cfg.clone());
    let bfp = BfpConfig::default();
    let mut t = Table::new(
        &format!("Dot product, n={n}, {trials} trials"),
        &["format", "rel RMS error", "norm events/job"],
    );
    let rms_h = dot::dot_rms_error::<Hrfna>(trials, n, Dist::moderate(), seed, &ctx);
    let norms = ctx.snapshot().norms as f64 / trials as f64;
    t.rowv(&["HRFNA".to_string(), format!("{:.3e}", rms_h), format!("{norms:.2}")]);
    let rms_f = dot::dot_rms_error::<f32>(trials, n, Dist::moderate(), seed, &());
    t.rowv(&["FP32".to_string(), format!("{:.3e}", rms_f), "n/a".to_string()]);
    let rms_b = dot::dot_rms_error::<Bfp>(trials, n, Dist::moderate(), seed, &bfp);
    t.rowv(&["BFP".to_string(), format!("{:.3e}", rms_b), "n/a".to_string()]);
    t.print();
}

fn cmd_matmul(args: &Args, cfg: &HrfnaConfig) {
    let dim = args.parse_or("dim", 64usize);
    let seed = args.parse_or("seed", 42u64);
    let ctx = HrfnaContext::new(cfg.clone());
    let mut t = Table::new(
        &format!("Matmul {dim}x{dim}"),
        &["format", "rel RMS error"],
    );
    let h = matmul::matmul_rms_error::<Hrfna>(dim, Dist::moderate(), seed, &ctx);
    t.rowv(&["HRFNA".to_string(), format!("{h:.3e}")]);
    let f = matmul::matmul_rms_error::<f32>(dim, Dist::moderate(), seed, &());
    t.rowv(&["FP32".to_string(), format!("{f:.3e}")]);
    let b = matmul::matmul_rms_error::<Bfp>(dim, Dist::moderate(), seed, &BfpConfig::default());
    t.rowv(&["BFP".to_string(), format!("{b:.3e}")]);
    t.print();
}

fn cmd_rk4(args: &Args, cfg: &HrfnaConfig) {
    let steps = args.parse_or("steps", 100_000u64);
    let dt = args.parse_or("dt", 0.002f64);
    let ctx = HrfnaContext::new(cfg.clone());
    let ode = rk4::Ode::VanDerPol { mu: 1.0 };
    let y0 = ode.default_y0();
    let every = (steps / 10).max(1);
    let mut t = Table::new(
        &format!("RK4 Van der Pol, {steps} steps, dt={dt}"),
        &["format", "max err vs f64", "drift ratio"],
    );
    let tr = rk4::rk4_integrate::<Hrfna>(&ode, &y0, dt, steps, every, &ctx);
    t.rowv(&["HRFNA".to_string(), eng(tr.max_error()), format!("{:.2}", tr.drift_ratio())]);
    let tf = rk4::rk4_integrate::<f32>(&ode, &y0, dt, steps, every, &());
    t.rowv(&["FP32".to_string(), eng(tf.max_error()), format!("{:.2}", tf.drift_ratio())]);
    let tb = rk4::rk4_integrate::<Bfp>(&ode, &y0, dt, steps, every, &BfpConfig::default());
    t.rowv(&["BFP".to_string(), eng(tb.max_error()), format!("{:.2}", tb.drift_ratio())]);
    t.print();
}

fn cmd_resources(cfg: &HrfnaConfig) {
    for kind in [
        WorkloadKind::Dot { n: 65536 },
        WorkloadKind::Matmul { m: 128, k: 128, n: 128 },
    ] {
        report::resource_table(cfg, kind, 16).print();
        let h = model_workload(FormatArch::Hrfna, kind, cfg, 16);
        let f = model_workload(FormatArch::Fp32, kind, cfg, 0);
        println!(
            "  speedup vs FP32: {:.2}x | LUT reduction: {:.0}%\n",
            speedup(&h, &f),
            report::lut_reduction_vs_fp32(cfg, kind, 16) * 100.0
        );
    }
}

fn cmd_tables() {
    // Qualitative tables are produced by the bench (shared code path).
    println!("run `cargo bench --bench bench_tables_qualitative` for Tables I/IV");
}

fn cmd_serve(args: &Args, cfg: &HrfnaConfig) {
    let jobs = args.parse_or("jobs", 200usize);
    let engine = EngineHandle::spawn(None).expect("engine (run `make artifacts`)");
    // The CLI-selected config becomes the registry's base (paper-slot)
    // tier; `lo`/`wide` keep their presets for escalation headroom.
    let registry = Arc::new(ContextRegistry::with_base(cfg.clone()));
    let backend =
        InProcess::new(Coordinator::start(engine, registry, CoordinatorConfig::default()));
    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    for i in 0..jobs {
        let n = 256 + rng.below(2048) as usize;
        let x = Dist::moderate().sample_vec(&mut rng, n);
        let y = Dist::moderate().sample_vec(&mut rng, n);
        let kind = if i % 2 == 0 { JobKind::DotHybrid } else { JobKind::DotF32 };
        pending.push(backend.submit(JobSpec::new(kind, Payload::Dot { x, y })).expect("submit"));
    }
    for ticket in pending {
        backend.wait(&ticket, DEFAULT_WAIT).expect("result");
    }
    println!("{}", backend.metrics_text());
    let drain = backend.shutdown().expect("shutdown once");
    println!("{drain}");
}

/// Serve an in-process coordinator over TCP JSON-RPC until a client
/// calls `shutdown`; exits 0 iff the drain was clean (every accepted
/// job replied to) — the invariant the CI smoke jobs assert. Run as
/// `serve-rpc` standalone or as `worker` under a cluster router (same
/// edge, cluster name).
#[cfg(feature = "rpc")]
fn cmd_serve_rpc(args: &Args, cfg: &HrfnaConfig, name: &str) {
    use hrfna::coordinator::rpc::{QuotaConfig, RpcServer, RpcServerConfig, MAX_FRAME_BYTES};

    let addr = args.str_or("addr", "127.0.0.1:9377");
    let quota = QuotaConfig {
        max_inflight: args.parse_or("max-inflight", 256usize),
        rate_per_s: args.parse_or("rate", 0.0f64),
        burst: args.parse_or("rate-burst", 64.0f64),
    };
    let max_frame_bytes = args.parse_or("max-frame", MAX_FRAME_BYTES);
    let engine = EngineHandle::spawn(None).expect("engine (run `make artifacts`)");
    let registry = Arc::new(ContextRegistry::with_base(cfg.clone()));
    let backend = Arc::new(InProcess::new(Coordinator::start(
        engine,
        registry,
        CoordinatorConfig::default(),
    )));
    let server = RpcServer::bind(
        Arc::clone(&backend) as Arc<dyn Backend>,
        &addr,
        RpcServerConfig { quota, max_frame_bytes, ..RpcServerConfig::default() },
    )
    .expect("bind rpc server");
    // The smoke test waits for this line before starting its load.
    println!("{name} listening on {}", server.local_addr());
    server.wait_shutdown();
    let wire = server.stop();
    wire.table().print();
    println!("{}", backend.metrics_text());
    let drain = backend.shutdown().expect("shutdown once");
    println!("{drain}");
    if !drain.is_clean() {
        eprintln!("{name}: unclean drain");
        std::process::exit(1);
    }
}

///// Cluster router: consistent-hash shard jobs across `--workers` (comma
/// separated `addr` or `id=addr`), serving clients over the same RPC
/// edge the workers speak. Exits 0 iff the router's own drain was clean
/// (no job accepted from a client was lost — the worker-kill smoke
/// test's invariant).
#[cfg(feature = "rpc")]
fn cmd_route(args: &Args) {
    use hrfna::coordinator::cluster::{parse_workers, RouterConfig, ShardRouter};
    use hrfna::coordinator::rpc::{QuotaConfig, RpcServer, RpcServerConfig};
    use std::time::Duration;

    let addr = args.str_or("addr", "127.0.0.1:9378");
    let workers = match args.get("workers").map(parse_workers) {
        Some(Ok(w)) => w,
        Some(Err(e)) => {
            eprintln!("route: bad --workers: {e}");
            std::process::exit(2);
        }
        None => {
            eprintln!("route: --workers addr[,addr...] (or id=addr) is required");
            std::process::exit(2);
        }
    };
    let router_cfg = RouterConfig {
        divert_depth: args.parse_or("divert-depth", 0i64),
        health_interval: Duration::from_millis(args.parse_or("health-interval-ms", 500u64)),
        // Coalescing is off unless a window is given: 0 µs keeps the
        // exact per-job submit path.
        coalesce_window: Duration::from_micros(args.parse_or("coalesce-us", 0u64)),
        coalesce_max: args.parse_or("coalesce-max", 8usize),
        ..RouterConfig::default()
    };
    let quota = QuotaConfig {
        max_inflight: args.parse_or("max-inflight", 256usize),
        rate_per_s: args.parse_or("rate", 0.0f64),
        burst: args.parse_or("rate-burst", 64.0f64),
    };
    let max_frame_bytes = args.parse_or("max-frame", hrfna::coordinator::rpc::MAX_FRAME_BYTES);
    let router = Arc::new(ShardRouter::start(workers, router_cfg).expect("cluster start"));
    let server = RpcServer::bind(
        Arc::clone(&router) as Arc<dyn Backend>,
        &addr,
        RpcServerConfig { quota, max_frame_bytes, ..RpcServerConfig::default() },
    )
    .expect("bind route server");
    println!(
        "route listening on {} ({} workers up)",
        server.local_addr(),
        router.up_count()
    );
    server.wait_shutdown();
    let wire = server.stop();
    wire.table().print();
    println!("{}", router.metrics_text());
    let drain = router.shutdown().expect("shutdown once");
    println!("{drain}");
    if !drain.is_clean() {
        eprintln!("route: unclean drain");
        std::process::exit(1);
    }
}

/// Socket-level closed-loop load against a running serve-rpc server.
/// Exits nonzero when nothing was served — a wedged accept loop or lost
/// wakeup turns into a CI failure, not a hang.
#[cfg(feature = "rpc")]
fn cmd_rpc_load(args: &Args) {
    use hrfna::coordinator::rpc::{socket_closed_loop_binary, ConnMode, RpcClient};
    use hrfna::coordinator::JobSpec;
    use hrfna::workloads::generators::ServeMix;
    use std::time::Duration;

    let addr = args.str_or("addr", "127.0.0.1:9377");
    let clients = args.parse_or("clients", 4usize);
    let jobs = args.parse_or("jobs", 48usize);
    let burst = args.parse_or("burst", 8usize);
    let mixed_tiers = args.flag("mixed-tiers");
    let authenticate = args.flag("authenticate");
    let binary = args.flag("binary");
    let mode = if args.flag("reconnect-per-job") { ConnMode::PerJob } else { ConnMode::Persistent };

    // Fail fast (with retries) if the server never comes up.
    RpcClient::connect_retry(&addr, Duration::from_secs(10))
        .expect("rpc server reachable")
        .ping()
        .expect("rpc server answers ping");

    let mix = ServeMix::default_mix();
    let make = |c: u64, i: usize| -> JobSpec {
        let (slot, mut rng) = mix.request_rng(c + 1, i);
        let spec = match slot {
            // With --authenticate, one of the four dot slots becomes a
            // FIR job so MAC lanes run end to end over both window
            // kinds; the unauthenticated mix is untouched.
            3 if authenticate => {
                let taps = hrfna::workloads::fir::lowpass_taps(16, 0.2);
                let x = mix.dist.sample_vec(&mut rng, mix.dot_n);
                JobSpec::fir(taps, x)
            }
            0..=3 => {
                let x = mix.dist.sample_vec(&mut rng, mix.dot_n);
                let y = mix.dist.sample_vec(&mut rng, mix.dot_n);
                JobSpec::new(JobKind::DotHybrid, Payload::Dot { x, y })
            }
            4..=6 => {
                let x = mix.dist.sample_vec(&mut rng, mix.dot_n);
                let y = mix.dist.sample_vec(&mut rng, mix.dot_n);
                JobSpec::new(JobKind::DotF32, Payload::Dot { x, y })
            }
            7 => {
                let a = mix.dist.sample_vec(&mut rng, mix.matmul_dim * mix.matmul_dim);
                let b = mix.dist.sample_vec(&mut rng, mix.matmul_dim * mix.matmul_dim);
                JobSpec::new(JobKind::MatmulHybrid, Payload::Matmul { a, b, dim: mix.matmul_dim })
            }
            8 => {
                let a = mix.dist.sample_vec(&mut rng, mix.matmul_dim * mix.matmul_dim);
                let b = mix.dist.sample_vec(&mut rng, mix.matmul_dim * mix.matmul_dim);
                JobSpec::new(JobKind::MatmulF32, Payload::Matmul { a, b, dim: mix.matmul_dim })
            }
            _ => JobSpec::new(
                JobKind::Rk4Hybrid,
                Payload::Rk4 { y0: vec![2.0, 0.0], mu: 1.0, dt: 0.01, steps: mix.rk4_steps },
            ),
        };
        let spec = if mixed_tiers && spec.kind.is_hybrid() {
            spec.tier(mix.tier_for(i))
        } else {
            spec
        };
        // MAC lanes exist only for the dot/fir/matmul hybrid kinds —
        // the rest of the mix stays unauthenticated (and bit-identical
        // to the pre-auth serving path).
        if authenticate
            && matches!(
                spec.kind,
                JobKind::DotHybrid | JobKind::FirHybrid | JobKind::MatmulHybrid
            )
        {
            spec.authenticated()
        } else {
            spec
        }
    };

    let report = socket_closed_loop_binary(&addr, clients, jobs, burst, mode, binary, &make);
    println!(
        "rpc-load: offered {} served {} rejected {} corrupted {} in {:.2?} ({:.0} jobs/s over the wire)",
        report.offered,
        report.completed,
        report.rejected,
        report.corrupted,
        report.wall,
        report.jobs_per_s
    );
    if let Some(lat) = &report.latency_us {
        println!("  latency p50 {:.0} us  p99 {:.0} us", lat.p50, lat.p99);
    }

    // The server's integrity view (detections + quarantined workers),
    // read before shutdown while the backend is still up. This is what
    // the fault-smoke tier gates on.
    let mut failed = false;
    if authenticate || args.flag("expect-detections") {
        let mut c = RpcClient::connect(&addr).expect("connect for health");
        let (detections, quarantined) = c.health_integrity().expect("health answers");
        println!("rpc-load: server integrity: detections {detections} quarantined {quarantined}");
        if args.flag("expect-detections") {
            if detections == 0 {
                eprintln!("rpc-load: expected integrity detections, server saw none");
                failed = true;
            }
            if quarantined == 0 {
                eprintln!("rpc-load: expected a quarantined worker, server has none");
                failed = true;
            }
        }
    }

    if args.flag("shutdown") {
        let mut c = RpcClient::connect(&addr).expect("connect for shutdown");
        c.shutdown_server().expect("server acknowledges shutdown");
        println!("rpc-load: server draining");
    }
    if report.corrupted > 0 {
        eprintln!("rpc-load: {} corrupted results delivered", report.corrupted);
        failed = true;
    }
    if report.completed == 0 {
        eprintln!("rpc-load: nothing served");
        failed = true;
    }
    if report.completed + report.rejected != report.offered {
        eprintln!("rpc-load: lost jobs (offered != served + rejected)");
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}

#[cfg(not(feature = "rpc"))]
fn cmd_serve_rpc(_args: &Args, _cfg: &HrfnaConfig, name: &str) {
    eprintln!("{name} requires the `rpc` feature: cargo run --features rpc -- {name}");
    std::process::exit(2);
}

#[cfg(not(feature = "rpc"))]
fn cmd_route(_args: &Args) {
    eprintln!("route requires the `rpc` feature: cargo run --features rpc -- route");
    std::process::exit(2);
}

#[cfg(not(feature = "rpc"))]
fn cmd_rpc_load(_args: &Args) {
    eprintln!("rpc-load requires the `rpc` feature: cargo run --features rpc -- rpc-load");
    std::process::exit(2);
}
