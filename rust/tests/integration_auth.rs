//! Integration: authenticated serving end to end through the coordinator
//! — MAC-verified dot/FIR windows and Freivalds-checked matmul deliver
//! the same values as the unauthenticated path plus a wire checksum,
//! unsupported kinds are rejected at admission, a clean run records zero
//! integrity detections, and unauthenticated traffic is untouched by the
//! auth machinery (no `check`, same values).

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::{
    Backend, ContextRegistry, Coordinator, CoordinatorConfig, Error, ExecMode, InProcess, JobKind,
    JobSpec, Tier,
};
use hrfna::hybrid::auth::values_checksum;
use hrfna::runtime::EngineHandle;
use hrfna::util::prng::Rng;
use hrfna::workloads::fir::lowpass_taps;
use hrfna::workloads::generators::Dist;
use std::sync::Arc;
use std::time::Duration;

fn coordinator() -> Coordinator {
    let engine = EngineHandle::spawn(None).expect("engine load");
    Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    )
}

#[test]
fn authenticated_dot_matches_unauthenticated_and_carries_checksum() {
    let coord = coordinator();
    let mut rng = Rng::new(17);
    for round in 0..4 {
        let n = 64 + rng.below(448) as usize;
        let x = Dist::moderate().sample_vec(&mut rng, n);
        let y = Dist::moderate().sample_vec(&mut rng, n);
        let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let plain = coord.call(JobSpec::dot(x.clone(), y.clone())).unwrap();
        let auth = coord.call(JobSpec::dot(x, y).authenticated()).unwrap();
        // The verified window dot reads the same planar lanes the
        // unauthenticated path decodes, so the delivered value is
        // bit-identical.
        assert_eq!(auth.values, plain.values, "round {round}: auth changed the value");
        assert!(
            (auth.values[0] - truth).abs() <= 1e-6 * truth.abs().max(1.0),
            "round {round}: got {} truth {truth}",
            auth.values[0]
        );
        assert_eq!(plain.check, None, "unauthenticated results carry no checksum");
        assert_eq!(
            auth.check,
            Some(values_checksum(&auth.values)),
            "round {round}: checksum must cover the delivered values"
        );
    }
    assert_eq!(coord.metrics.total_integrity_detections(), 0, "clean run");
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn authenticated_fir_is_verified_and_accurate() {
    let coord = coordinator();
    let mut rng = Rng::new(29);
    let taps = lowpass_taps(12, 0.2);
    let n = 96;
    let x = Dist::moderate().sample_vec(&mut rng, n);
    // Direct-form f64 reference with zero-padded history.
    let want: Vec<f64> = (0..n)
        .map(|t| {
            taps.iter()
                .enumerate()
                .filter(|(i, _)| *i <= t)
                .map(|(i, &h)| h * x[t - i])
                .sum()
        })
        .collect();
    let scale = want.iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(1.0);
    let r = coord.call(JobSpec::fir(taps, x).authenticated()).unwrap();
    assert_eq!(r.kind, JobKind::FirHybrid);
    assert_eq!(r.values.len(), n);
    for (t, (&got, &w)) in r.values.iter().zip(&want).enumerate() {
        assert!(
            (got - w).abs() <= 1e-7 * scale,
            "output {t}: got {got} want {w}"
        );
    }
    assert_eq!(r.check, Some(values_checksum(&r.values)));
    assert_eq!(coord.metrics.total_integrity_detections(), 0);
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn authenticated_matmul_passes_freivalds_and_matches_plain() {
    let coord = coordinator();
    let mut rng = Rng::new(31);
    let dim = 64;
    let a: Vec<f64> = (0..dim * dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
    let b: Vec<f64> = (0..dim * dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
    let plain = coord.call(JobSpec::matmul(a.clone(), b.clone(), dim)).unwrap();
    let auth = coord.call(JobSpec::matmul(a, b, dim).authenticated()).unwrap();
    // Freivalds verifies the product computed on the normal datapath; it
    // never changes it.
    assert_eq!(auth.values, plain.values, "verification must not alter the product");
    assert_eq!(plain.check, None);
    assert_eq!(auth.check, Some(values_checksum(&auth.values)));
    assert_eq!(coord.metrics.total_integrity_detections(), 0);
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn authentication_rejected_for_kinds_without_mac_lanes() {
    let coord = coordinator();
    let mut rng = Rng::new(37);
    let x = Dist::moderate().sample_vec(&mut rng, 128);
    let y = Dist::moderate().sample_vec(&mut rng, 128);
    // FP32 lanes have no residues; RK4 has no per-job verification hook.
    let fp32 = coord.call(JobSpec::dot_f32(x, y).authenticated());
    assert!(matches!(fp32, Err(Error::Rejected(_))), "got {fp32:?}");
    let rk4 = coord.call(JobSpec::rk4(vec![2.0, 0.0], 1.5, 0.01, 32).authenticated());
    assert!(matches!(rk4, Err(Error::Rejected(_))), "got {rk4:?}");
    assert_eq!(coord.metrics.total_rejected(), 2);
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn backend_surfaces_integrity_counters_for_the_health_edge() {
    // The Backend seam the health RPC reads: a clean in-process run
    // reports zero detections and has no workers to quarantine.
    let backend = InProcess::new(coordinator());
    let mut rng = Rng::new(43);
    let x = Dist::moderate().sample_vec(&mut rng, 256);
    let y = Dist::moderate().sample_vec(&mut rng, 256);
    let r = backend.call(JobSpec::dot(x, y).authenticated()).unwrap();
    assert!(r.check.is_some());
    assert_eq!(backend.integrity_detections(), 0);
    assert_eq!(backend.quarantined_workers(), 0);
    assert!(backend.shutdown().unwrap().is_clean());
}

#[test]
fn mixed_batches_serve_authenticated_and_plain_riders_together() {
    // Pipelined auth + plain submissions of the same bucket land in the
    // same batches; each job keeps its own contract (checksummed vs not).
    let coord = coordinator();
    let mut rng = Rng::new(47);
    let mut pending = Vec::new();
    let mut truths = Vec::new();
    for i in 0..16usize {
        let x = Dist::moderate().sample_vec(&mut rng, 300);
        let y = Dist::moderate().sample_vec(&mut rng, 300);
        truths.push(x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>());
        let spec = JobSpec::dot(x, y);
        let spec = if i % 2 == 0 { spec.authenticated() } else { spec };
        pending.push((i, coord.submit(spec).unwrap()));
    }
    for (i, rx) in pending {
        let r = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("job completes")
            .expect("job succeeds");
        assert!(
            (r.values[0] - truths[i]).abs() <= 1e-6 * truths[i].abs().max(1.0),
            "job {i}"
        );
        if i % 2 == 0 {
            assert_eq!(r.check, Some(values_checksum(&r.values)), "job {i} authenticated");
        } else {
            assert_eq!(r.check, None, "job {i} is a plain rider");
        }
    }
    assert_eq!(coord.metrics.total_integrity_detections(), 0);
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}
