//! Integration: seeded fault injection against the authenticated serving
//! path (`--features fault-inject`). With the process-wide injector armed
//! at rate 1.0, every authenticated job is corrupted between MAC
//! derivation and verification — and every one must come back as a typed
//! `IntegrityFailure`, never as delivered values. Unauthenticated
//! traffic shares the same lanes and batches and must be untouched.
//!
//! This lives in its own test binary because [`hrfna::util::faults::install`]
//! is process-wide (first call wins): arming it here cannot leak faults
//! into the clean-path auth tests.
#![cfg(feature = "fault-inject")]

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::{
    ContextRegistry, Coordinator, CoordinatorConfig, Error, ExecMode, JobKind, JobSpec, Tier,
};
use hrfna::runtime::EngineHandle;
use hrfna::util::faults::{install, FaultPlan};
use hrfna::util::prng::Rng;
use hrfna::workloads::fir::lowpass_taps;
use hrfna::workloads::generators::Dist;
use std::sync::Arc;
use std::time::Duration;

fn arm() {
    // First call wins; rate 1.0 makes every corruption opportunity fire,
    // so detection assertions below are deterministic, not statistical.
    let _ = install(FaultPlan { rate: 1.0, seed: 7 });
}

fn coordinator() -> Coordinator {
    let engine = EngineHandle::spawn(None).expect("engine load");
    Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    )
}

#[test]
fn every_authenticated_job_is_corrupted_and_detected_never_delivered() {
    arm();
    let coord = coordinator();
    let mut rng = Rng::new(53);
    let mut auth_jobs = 0u64;
    for round in 0..6 {
        let x = Dist::moderate().sample_vec(&mut rng, 256);
        let y = Dist::moderate().sample_vec(&mut rng, 256);
        let spec = match round % 3 {
            0 => JobSpec::dot(x, y),
            1 => JobSpec::fir(lowpass_taps(8, 0.25), x),
            _ => {
                let dim = 64;
                let a: Vec<f64> = (0..dim * dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
                let b: Vec<f64> = (0..dim * dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
                JobSpec::matmul(a, b, dim)
            }
        };
        auth_jobs += 1;
        let kind = spec.kind;
        let out = coord.call(spec.authenticated());
        match out {
            Err(Error::IntegrityFailure(msg)) => {
                assert!(!msg.is_empty(), "{kind:?}: failure must say what broke");
            }
            other => panic!(
                "{kind:?}: corrupted job must fail with IntegrityFailure, got {other:?}"
            ),
        }
    }
    // The zero-corrupted-delivered invariant: every corruption was caught
    // and counted; nothing reached a client as values.
    assert_eq!(coord.metrics.total_integrity_detections(), auth_jobs);
    assert!(coord.metrics.integrity_tier(JobKind::DotHybrid, Tier::Paper) > 0);
    assert!(coord.metrics.integrity_tier(JobKind::FirHybrid, Tier::Paper) > 0);
    assert!(coord.metrics.integrity_tier(JobKind::MatmulHybrid, Tier::Paper) > 0);

    // Unauthenticated traffic rides the same lanes with the injector
    // armed and is never corrupted (the injectors only target
    // authenticated jobs' windows).
    for _ in 0..4 {
        let x = Dist::moderate().sample_vec(&mut rng, 256);
        let y = Dist::moderate().sample_vec(&mut rng, 256);
        let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let r = coord.call(JobSpec::dot(x, y)).expect("plain job unaffected");
        assert!((r.values[0] - truth).abs() <= 1e-6 * truth.abs().max(1.0));
        assert_eq!(r.check, None);
    }

    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn injector_reports_the_armed_plan() {
    arm();
    let inj = hrfna::util::faults::global().expect("armed in this binary");
    assert_eq!(inj.plan().rate, 1.0);
    assert_eq!(inj.plan().seed, 7);
}
