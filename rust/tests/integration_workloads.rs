//! Integration across the pure-Rust stack (no PJRT): workloads × formats
//! cross-checks reproducing the paper's §VII accuracy claims at test scale,
//! plus end-to-end property tests of the numeric system.

use hrfna::baselines::{Bfp, BfpConfig, Fixed, FixedConfig, Lns, LnsConfig};
use hrfna::config::HrfnaConfig;
use hrfna::hybrid::{error, Hrfna, HrfnaContext};
use hrfna::util::proptest::check_with;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::Dist;
use hrfna::workloads::rk4::{rk4_integrate, Ode};
use hrfna::workloads::traits::Numeric;
use hrfna::workloads::{dot, matmul};

#[test]
fn paper_claim_dot_rms_below_1e6_all_lengths() {
    // §VII-B.3: "Across all tested vector lengths, HRFNA maintains RMS
    // error below 1e-6" — test-scale lengths here; full sweep in benches.
    let ctx = HrfnaContext::paper_default();
    for n in [1024usize, 4096, 16384] {
        let rms = dot::dot_rms_error::<Hrfna>(2, n, Dist::moderate(), 7, &ctx);
        assert!(rms < 1e-6, "n={n} rms={rms}");
    }
}

#[test]
fn paper_claim_dot_stable_under_high_dynamic_range() {
    let ctx = HrfnaContext::paper_default();
    let rms = dot::dot_rms_error::<Hrfna>(3, 4096, Dist::high_dynamic_range(), 13, &ctx);
    // Relative RMS still tracks the reference closely.
    assert!(rms < 1e-5, "rms={rms}");
}

#[test]
fn paper_claim_error_flat_vs_length_hrfna_growing_bfp() {
    // §VII-B.3: HRFNA error does not exhibit the linear growth BFP shows.
    let ctx = HrfnaContext::paper_default();
    let bfp = BfpConfig::default();
    let h_small = dot::dot_rms_error::<Hrfna>(3, 1024, Dist::moderate(), 3, &ctx);
    let h_large = dot::dot_rms_error::<Hrfna>(3, 16384, Dist::moderate(), 3, &ctx);
    let b_small = dot::dot_rms_error::<Bfp>(3, 1024, Dist::moderate(), 3, &bfp);
    let b_large = dot::dot_rms_error::<Bfp>(3, 16384, Dist::moderate(), 3, &bfp);
    assert!(h_large < h_small * 20.0, "HRFNA error must stay ~flat");
    assert!(
        b_large > b_small,
        "BFP error should grow with N: {b_small} -> {b_large}"
    );
    assert!(b_large > h_large * 100.0, "BFP must be far worse than HRFNA");
}

#[test]
fn paper_claim_matmul_rms_below_2e6() {
    // §VII-C.3 at test scale (64 in benches).
    let ctx = HrfnaContext::paper_default();
    let rms = matmul::matmul_rms_error::<Hrfna>(24, Dist::moderate(), 5, &ctx);
    assert!(rms < 2e-6, "rms={rms}");
}

#[test]
fn paper_claim_rk4_bounded_error_bfp_drifts() {
    // §VII-D.3 at 20k steps: HRFNA bounded, BFP visibly worse.
    let ctx = HrfnaContext::paper_default();
    let ode = Ode::DampedOscillator { omega: 1.0, zeta: 0.05 };
    let steps = 20_000;
    let h = rk4_integrate::<Hrfna>(&ode, &[1.0, 0.0], 0.005, steps, 2000, &ctx);
    let f = rk4_integrate::<f32>(&ode, &[1.0, 0.0], 0.005, steps, 2000, &());
    let b = rk4_integrate::<Bfp>(&ode, &[1.0, 0.0], 0.005, steps, 2000, &BfpConfig::default());
    assert!(h.max_error() < 1e-5, "HRFNA err={}", h.max_error());
    assert!(h.max_error() <= f.max_error() * 2.0 + 1e-9, "HRFNA must be FP32-class");
    assert!(b.max_error() > h.max_error() * 50.0, "BFP should drift: {}", b.max_error());
}

#[test]
fn normalization_rate_once_per_thousands_of_ops() {
    // §VII-E: "normalization events occur orders of magnitude less
    // frequently than arithmetic operations, typically once per several
    // thousand operations" — with the paper's moderate operand
    // distribution the default threshold is essentially never hit; a
    // tightened threshold (stress preset) shows the once-per-thousands
    // regime.
    let ctx = HrfnaContext::paper_default();
    ctx.reset_counters();
    let _ = dot::dot_rms_error::<Hrfna>(2, 8192, Dist::moderate(), 21, &ctx);
    let snap = ctx.snapshot();
    assert!(snap.arithmetic_ops() > 30_000);
    assert!(snap.norm_rate() < 1e-4, "rate {} too high", snap.norm_rate());

    // High-dynamic-range operands: events occur but stay orders of
    // magnitude rarer than arithmetic ops.
    ctx.reset_counters();
    let _ = dot::dot_rms_error::<Hrfna>(2, 8192, Dist::high_dynamic_range(), 21, &ctx);
    let rate = ctx.snapshot().norm_rate();
    assert!(rate > 0.0, "HDR should trigger events");
    assert!(rate < 5e-3, "rate {rate} should stay rare");

    // Tight-threshold stress preset: events become regular but bounded,
    // and accuracy still holds (checked in lemma_bounds test).
    let tight = HrfnaContext::new(HrfnaConfig::preset("stress-norm").unwrap());
    let _ = dot::dot_rms_error::<Hrfna>(2, 8192, Dist::moderate(), 21, &tight);
    let tight_rate = tight.snapshot().norm_rate();
    assert!(tight_rate > 0.0);
    assert!(tight_rate < 1e-2, "stress rate {tight_rate}");
}

#[test]
fn mismatched_exponent_workloads_pay_more_syncs() {
    // §IX-B limitation, reproduced: extreme magnitude mixing forces
    // frequent lossy exponent synchronization.
    let ctx = HrfnaContext::paper_default();
    ctx.reset_counters();
    let _ = dot::dot_rms_error::<Hrfna>(1, 2048, Dist::Mixed, 21, &ctx);
    let mixed_rate = ctx.snapshot().norm_rate();
    ctx.reset_counters();
    let _ = dot::dot_rms_error::<Hrfna>(1, 2048, Dist::moderate(), 21, &ctx);
    let moderate_rate = ctx.snapshot().norm_rate();
    assert!(
        mixed_rate > moderate_rate * 10.0,
        "mixed={mixed_rate} moderate={moderate_rate}"
    );
}

#[test]
fn lemma_bounds_hold_through_workloads() {
    // Run a workload with a tight threshold, then verify sampled
    // normalization events stay within the Lemma 1 bound.
    let cfg = HrfnaConfig {
        tau_bits: 72,
        ..HrfnaConfig::paper_default()
    };
    let ctx = HrfnaContext::new(cfg);
    let mut rng = Rng::new(77);
    check_with("workload-lemma1", 32, |r| {
        let bits = 34 + r.below(30) as u32;
        let n = (r.next_u64() >> (64 - bits)).max(3) as i64;
        let mut v = Hrfna::from_signed_int(if r.bool() { n } else { -n }, -40, &ctx);
        let s = 1 + r.below(20) as u32;
        let sample = error::measure_normalization(&mut v, s, &ctx);
        if !sample.within_bounds() {
            return Err(format!("violation: {sample:?}"));
        }
        Ok(())
    });
    // And a dot product under the tight threshold still tracks f64.
    let xs = Dist::moderate().sample_vec(&mut rng, 4096);
    let ys = Dist::moderate().sample_vec(&mut rng, 4096);
    let want = dot::dot_product::<f64>(&xs, &ys, &());
    let got = dot::dot_product::<Hrfna>(&xs, &ys, &ctx);
    assert!((got - want).abs() < 1e-5 * want.abs().max(1.0));
    assert!(ctx.snapshot().norms > 0, "tight threshold must trigger events");
}

#[test]
fn fixed_point_saturates_where_hrfna_survives() {
    // Table I dynamic-range row: fixed-point fails multi-scale operands.
    let fctx = FixedConfig::q16_16();
    let hctx = HrfnaContext::paper_default();
    let xs = [1.0e4, 2.0e4, -1.5e4, 3.0e4];
    let ys = [1.0e4, 1.0e4, 1.0e4, 1.0e4];
    let want: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
    let got_fixed = dot::dot_product::<Fixed>(&xs, &ys, &fctx);
    let got_h = dot::dot_product::<Hrfna>(&xs, &ys, &hctx);
    assert!(fctx.saturation_count() > 0, "fixed point should saturate");
    assert!((got_fixed - want).abs() > want.abs() * 0.5, "fixed result is clamped");
    assert!((got_h - want).abs() < want.abs() * 1e-6);
}

#[test]
fn lns_mul_cheap_add_lossy() {
    // Table I LNS characteristics: multiplication exact-ish, addition
    // approximate and counted.
    let ctx = LnsConfig::default();
    let xs = Dist::moderate().sample_vec(&mut Rng::new(31), 512);
    let ys = Dist::moderate().sample_vec(&mut Rng::new(32), 512);
    let want = dot::dot_product::<f64>(&xs, &ys, &());
    let got = dot::dot_product::<Lns>(&xs, &ys, &ctx);
    // LNS dot accumulates Gaussian-log approximation error.
    assert!((got - want).abs() < want.abs().max(1.0) * 0.01);
    // 511 counted adds: the first MAC adds into a zero accumulator,
    // which short-circuits without the Gaussian-log path.
    assert!(ctx.addsub_ops.load(std::sync::atomic::Ordering::Relaxed) >= 500);
}

#[test]
fn cross_format_dot_error_ordering() {
    // The qualitative Table I/IV ordering, measured: HRFNA ≤ FP32 < BFP.
    let hctx = HrfnaContext::paper_default();
    let h = dot::dot_rms_error::<Hrfna>(3, 4096, Dist::moderate(), 99, &hctx);
    let f = dot::dot_rms_error::<f32>(3, 4096, Dist::moderate(), 99, &());
    let b = dot::dot_rms_error::<Bfp>(3, 4096, Dist::moderate(), 99, &BfpConfig::default());
    assert!(h <= f, "HRFNA ({h}) must match or beat FP32 ({f})");
    assert!(f < b, "FP32 ({f}) must beat BFP ({b})");
}

#[test]
fn prop_dot_product_permutation_stability() {
    // Exact residue accumulation ⇒ order-independence between
    // normalization events: shuffling operands must not change the result
    // beyond encode rounding (FP32 famously fails this).
    let ctx = HrfnaContext::paper_default();
    check_with("dot-permutation", 16, |rng| {
        let n = 256;
        let xs = Dist::moderate().sample_vec(rng, n);
        let ys = Dist::moderate().sample_vec(rng, n);
        let base = dot::dot_product::<Hrfna>(&xs, &ys, &ctx);
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let xs2: Vec<f64> = idx.iter().map(|&i| xs[i]).collect();
        let ys2: Vec<f64> = idx.iter().map(|&i| ys[i]).collect();
        let shuffled = dot::dot_product::<Hrfna>(&xs2, &ys2, &ctx);
        let tol = 1e-9 * base.abs().max(1e-12);
        if (base - shuffled).abs() > tol {
            return Err(format!("order dependence: {base} vs {shuffled}"));
        }
        Ok(())
    });
}
