//! Integration: the runtime engine executes the AOT graphs and their
//! numerics agree bit-for-bit with the pure-Rust residue model and within
//! tolerance of f64 — the critical L1 ↔ L3 cross-check.
//!
//! Runs against whichever backend the build selected: the default
//! pure-Rust software executor (no artifacts needed), or — with
//! `--features xla` — the real PJRT client, which additionally requires
//! `make artifacts` (the Makefile `test` target guarantees it).

use hrfna::coordinator::hybrid_exec::{decode_matrix, decode_scalar, encode_block};
use hrfna::hybrid::HrfnaContext;
use hrfna::runtime::pjrt::{Engine, Tensor};
use hrfna::runtime::Manifest;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::Dist;

const DOT_N: usize = 4096;
const MM_DIM: usize = 64;

fn engine() -> Engine {
    Engine::load(&Manifest::default_dir()).expect("run `make artifacts` first")
}

fn moduli_tensor(ctx: &HrfnaContext) -> Tensor {
    let m: Vec<i64> = ctx.cfg.moduli.iter().map(|&v| v as i64).collect();
    Tensor::I64(m, vec![ctx.k()])
}

#[test]
fn engine_loads_all_artifacts() {
    let e = engine();
    let names = e.names();
    for expected in [
        "hybrid_dot",
        "hybrid_matmul",
        "hybrid_modmul",
        "hybrid_modadd",
        "fp32_dot",
        "fp32_matmul",
        "rk4_vdp_step",
    ] {
        assert!(names.iter().any(|n| n == expected), "missing {expected}");
    }
}

#[test]
fn hybrid_dot_kernel_matches_software_residue_math_bitexact() {
    let e = engine();
    let ctx = HrfnaContext::paper_default();
    let mut rng = Rng::new(11);
    let xs = Dist::moderate().sample_vec(&mut rng, DOT_N);
    let ys = Dist::moderate().sample_vec(&mut rng, DOT_N);
    let ex = encode_block(&xs, &ctx);
    let ey = encode_block(&ys, &ctx);

    // Software reference: channelwise modular MAC on the same residues.
    let k = ctx.k();
    let mut want = vec![0i64; k];
    for c in 0..k {
        let m = ctx.cfg.moduli[c] as i128;
        let mut acc = 0i128;
        for j in 0..DOT_N {
            acc = (acc
                + ex.residues[c * DOT_N + j] as i128 * ey.residues[c * DOT_N + j] as i128)
                % m;
        }
        want[c] = acc as i64;
    }

    let got = e
        .execute(
            "hybrid_dot",
            &[
                Tensor::I64(ex.residues.clone(), vec![k, DOT_N]),
                Tensor::I64(ey.residues.clone(), vec![k, DOT_N]),
                moduli_tensor(&ctx),
            ],
        )
        .unwrap()
        .into_i64()
        .unwrap();
    assert_eq!(got, want, "kernel residues differ from software residues");

    // And the decoded value matches f64 within block-encoding error.
    let value = decode_scalar(&got, ex.f + ey.f, &ctx);
    let truth: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
    assert!(
        ((value - truth) / truth.abs().max(1e-30)).abs() < 1e-6,
        "value={value} truth={truth}"
    );
}

#[test]
fn fp32_dot_kernel_matches_host_f32() {
    let e = engine();
    let mut rng = Rng::new(5);
    let xs: Vec<f32> = (0..DOT_N).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let ys: Vec<f32> = (0..DOT_N).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let got = e
        .execute(
            "fp32_dot",
            &[
                Tensor::F32(xs.clone(), vec![DOT_N]),
                Tensor::F32(ys.clone(), vec![DOT_N]),
            ],
        )
        .unwrap()
        .into_f32()
        .unwrap()[0];
    let want: f64 = xs.iter().zip(&ys).map(|(&a, &b)| a as f64 * b as f64).sum();
    assert!((got as f64 - want).abs() < 1e-2, "got={got} want={want}");
}

#[test]
fn hybrid_matmul_kernel_matches_f64() {
    let e = engine();
    let ctx = HrfnaContext::paper_default();
    let mut rng = Rng::new(23);
    let a = Dist::moderate().sample_vec(&mut rng, MM_DIM * MM_DIM);
    let b = Dist::moderate().sample_vec(&mut rng, MM_DIM * MM_DIM);
    let ea = encode_block(&a, &ctx);
    let eb = encode_block(&b, &ctx);
    let k = ctx.k();
    let got = e
        .execute(
            "hybrid_matmul",
            &[
                Tensor::I64(ea.residues, vec![k, MM_DIM, MM_DIM]),
                Tensor::I64(eb.residues, vec![k, MM_DIM, MM_DIM]),
                moduli_tensor(&ctx),
            ],
        )
        .unwrap()
        .into_i64()
        .unwrap();
    let vals = decode_matrix(&got, MM_DIM * MM_DIM, ea.f + eb.f, &ctx);

    // f64 reference.
    for i in 0..MM_DIM {
        for j in 0..MM_DIM {
            let mut truth = 0.0;
            for p in 0..MM_DIM {
                truth += a[i * MM_DIM + p] * b[p * MM_DIM + j];
            }
            let gotv = vals[i * MM_DIM + j];
            assert!(
                (gotv - truth).abs() < 1e-6 * truth.abs().max(1.0),
                "({i},{j}): got={gotv} truth={truth}"
            );
        }
    }
}

#[test]
fn elementwise_kernels_match_residue_ops_bitexact() {
    let e = engine();
    let ctx = HrfnaContext::paper_default();
    let k = ctx.k();
    let mut rng = Rng::new(37);
    let mut x = vec![0i64; k * DOT_N];
    let mut y = vec![0i64; k * DOT_N];
    for c in 0..k {
        let m = ctx.cfg.moduli[c];
        for j in 0..DOT_N {
            x[c * DOT_N + j] = (rng.below(m)) as i64;
            y[c * DOT_N + j] = (rng.below(m)) as i64;
        }
    }
    let cases: [(&str, fn(i128, i128, i128) -> i128); 2] = [
        ("hybrid_modmul", |a, b, m| a * b % m),
        ("hybrid_modadd", |a, b, m| (a + b) % m),
    ];
    for (name, op) in cases {
        let got = e
            .execute(
                name,
                &[
                    Tensor::I64(x.clone(), vec![k, DOT_N]),
                    Tensor::I64(y.clone(), vec![k, DOT_N]),
                    moduli_tensor(&ctx),
                ],
            )
            .unwrap()
            .into_i64()
            .unwrap();
        for c in 0..k {
            let m = ctx.cfg.moduli[c] as i128;
            for j in 0..DOT_N {
                let idx = c * DOT_N + j;
                let want = op(x[idx] as i128, y[idx] as i128, m) as i64;
                assert_eq!(got[idx], want, "{name} mismatch at ({c},{j})");
            }
        }
    }
}

#[test]
fn rk4_step_kernel_matches_host_step() {
    let e = engine();
    let b = 256;
    let mut rng = Rng::new(41);
    let state: Vec<f32> = (0..b * 2).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
    let dt = 0.01f32;
    let mu = 1.5f32;
    let got = e
        .execute(
            "rk4_vdp_step",
            &[
                Tensor::F32(state.clone(), vec![b, 2]),
                Tensor::ScalarF32(dt),
                Tensor::ScalarF32(mu),
            ],
        )
        .unwrap()
        .into_f32()
        .unwrap();

    // Host reference (f32 arithmetic, same RK4).
    let f = |s: &[f32; 2]| -> [f32; 2] {
        [s[1], mu * (1.0 - s[0] * s[0]) * s[1] - s[0]]
    };
    for i in 0..b {
        let s = [state[i * 2], state[i * 2 + 1]];
        let k1 = f(&s);
        let s2 = [s[0] + 0.5 * dt * k1[0], s[1] + 0.5 * dt * k1[1]];
        let k2 = f(&s2);
        let s3 = [s[0] + 0.5 * dt * k2[0], s[1] + 0.5 * dt * k2[1]];
        let k3 = f(&s3);
        let s4 = [s[0] + dt * k3[0], s[1] + dt * k3[1]];
        let k4 = f(&s4);
        for d in 0..2 {
            let want = s[d] + dt / 6.0 * (k1[d] + 2.0 * k2[d] + 2.0 * k3[d] + k4[d]);
            let gotv = got[i * 2 + d];
            assert!(
                (gotv - want).abs() < 1e-4,
                "state {i} dim {d}: got={gotv} want={want}"
            );
        }
    }
}

#[test]
fn shape_validation_rejects_wrong_inputs() {
    let e = engine();
    let bad = e.execute(
        "fp32_dot",
        &[
            Tensor::F32(vec![0.0; 8], vec![8]),
            Tensor::F32(vec![0.0; 8], vec![8]),
        ],
    );
    assert!(bad.is_err(), "wrong shape must be rejected");
    let bad = e.execute("fp32_dot", &[Tensor::F32(vec![0.0; DOT_N], vec![DOT_N])]);
    assert!(bad.is_err(), "wrong arity must be rejected");
    assert!(e.execute("nonexistent", &[]).is_err());
}
