//! Integration: the encoded-operand cache end to end through the
//! coordinator — cache-served matmul weights and FIR taps deliver
//! bit-identical results to a cold-encoding coordinator across all three
//! tiers, authenticated jobs verify their MACs on cache hits, and
//! invalidation forces a re-encode (never a stale serve). The cache is a
//! pure memoization of the encode step, so every assertion here is exact
//! (`to_bits`), not a tolerance.

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::{
    ContextRegistry, Coordinator, CoordinatorConfig, ExecMode, JobKind, JobSpec, Tier,
};
use hrfna::hybrid::auth::values_checksum;
use hrfna::runtime::EngineHandle;
use hrfna::util::prng::Rng;
use hrfna::workloads::fir::lowpass_taps;
use hrfna::workloads::generators::Dist;
use std::sync::Arc;
use std::time::Duration;

const DIM: usize = 64;
const FIR_N: usize = 96;

fn coordinator_with(op_cache_bytes: usize) -> Coordinator {
    let engine = EngineHandle::spawn(None).expect("engine load");
    Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            exec: ExecMode::Planar,
            op_cache_bytes,
            ..CoordinatorConfig::default()
        },
    )
}

fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} differs ({g} vs {w})"
        );
    }
}

#[test]
fn cache_served_matmul_and_fir_bit_identical_to_cold_encode_across_tiers() {
    // Same traffic through a cached coordinator (reused weights/taps hit
    // after the first encode) and a cache-disabled one; the cache must be
    // numerically invisible at every tier.
    let cached = coordinator_with(32 << 20);
    let cold = coordinator_with(0);
    assert!(cached.op_cache().is_some());
    assert!(cold.op_cache().is_none(), "op_cache_bytes: 0 disables the cache");

    let mut rng = Rng::new(61);
    let b: Vec<f64> = Dist::moderate().sample_vec(&mut rng, DIM * DIM);
    let taps = lowpass_taps(12, 0.15);

    for tier in Tier::ALL {
        for round in 0..3 {
            let a = Dist::moderate().sample_vec(&mut rng, DIM * DIM);
            let hot = cached
                .call(JobSpec::matmul(a.clone(), b.clone(), DIM).tier(tier))
                .expect("cached matmul");
            let reference = cold
                .call(JobSpec::matmul(a, b.clone(), DIM).tier(tier))
                .expect("cold matmul");
            assert_bits_eq(
                &hot.values,
                &reference.values,
                &format!("matmul tier {tier:?} round {round}"),
            );

            let x = Dist::moderate().sample_vec(&mut rng, FIR_N);
            let hot = cached
                .call(JobSpec::fir(taps.clone(), x.clone()).tier(tier))
                .expect("cached fir");
            let reference = cold
                .call(JobSpec::fir(taps.clone(), x).tier(tier))
                .expect("cold fir");
            assert_bits_eq(
                &hot.values,
                &reference.values,
                &format!("fir tier {tier:?} round {round}"),
            );
        }
        // Sequential calls, one lookup per job: encode once, hit twice —
        // and the key is tier-scoped, so each tier pays its own miss.
        for kind in [JobKind::MatmulHybrid, JobKind::FirHybrid] {
            assert_eq!(cached.metrics.cache_misses_tier(kind, tier), 1, "{kind:?} {tier:?}");
            assert_eq!(cached.metrics.cache_hits_tier(kind, tier), 2, "{kind:?} {tier:?}");
        }
    }
    // The disabled side never touched a cache.
    assert_eq!(cold.metrics.cache_hits(JobKind::MatmulHybrid), 0);
    assert_eq!(cold.metrics.cache_misses(JobKind::MatmulHybrid), 0);

    assert!(cached.shutdown().is_clean());
    assert!(cold.shutdown().is_clean());
}

#[test]
fn cache_served_rk4_coeffs_bit_identical_to_cold_encode_across_tiers() {
    // RK4 jobs cache the vector field's pre-encoded constant table
    // (keyed by the ODE's constants per tier); a cache-served
    // integration must reproduce the cold-encoding coordinator bit for
    // bit at every tier — the table is a pure memoization of a
    // deterministic encode.
    let cached = coordinator_with(32 << 20);
    let cold = coordinator_with(0);
    let mut rng = Rng::new(79);
    for tier in Tier::ALL {
        for round in 0..3 {
            let y0 = vec![rng.uniform(-1.5, 1.5), rng.uniform(-1.5, 1.5)];
            let hot = cached
                .call(JobSpec::rk4(y0.clone(), 1.0, 0.01, 200).tier(tier))
                .expect("cached rk4");
            let reference = cold
                .call(JobSpec::rk4(y0, 1.0, 0.01, 200).tier(tier))
                .expect("cold rk4");
            assert_bits_eq(
                &hot.values,
                &reference.values,
                &format!("rk4 tier {tier:?} round {round}"),
            );
        }
        // One constant table per (mu, tier): encode once, hit twice.
        assert_eq!(cached.metrics.cache_misses_tier(JobKind::Rk4Hybrid, tier), 1, "{tier:?}");
        assert_eq!(cached.metrics.cache_hits_tier(JobKind::Rk4Hybrid, tier), 2, "{tier:?}");
    }
    assert_eq!(cold.metrics.cache_hits(JobKind::Rk4Hybrid), 0);
    assert_eq!(cold.metrics.cache_misses(JobKind::Rk4Hybrid), 0);

    assert!(cached.shutdown().is_clean());
    assert!(cold.shutdown().is_clean());
}

#[test]
fn authenticated_jobs_verify_macs_on_cache_hits() {
    // Authenticated FIR derives per-job MAC lanes from the *cached*
    // reversed-tap plane; authenticated matmul Freivalds-checks a product
    // computed off the cached RHS. Both must keep verifying — and keep
    // matching a cold coordinator bit for bit — once the operands are
    // served from cache.
    let cached = coordinator_with(32 << 20);
    let cold = coordinator_with(0);
    let mut rng = Rng::new(67);
    let a = Dist::moderate().sample_vec(&mut rng, DIM * DIM);
    let b = Dist::moderate().sample_vec(&mut rng, DIM * DIM);
    let taps = lowpass_taps(10, 0.2);
    let x = Dist::moderate().sample_vec(&mut rng, 80);

    let plain = cached
        .call(JobSpec::matmul(a.clone(), b.clone(), DIM))
        .expect("plain matmul");
    let cold_fir = cold
        .call(JobSpec::fir(taps.clone(), x.clone()).authenticated())
        .expect("cold auth fir");
    for round in 0..3 {
        let auth = cached
            .call(JobSpec::matmul(a.clone(), b.clone(), DIM).authenticated())
            .expect("auth matmul");
        // Freivalds rides on the unchanged (cached) product datapath.
        assert_bits_eq(&auth.values, &plain.values, &format!("auth matmul round {round}"));
        assert_eq!(auth.check, Some(values_checksum(&auth.values)));

        let auth = cached
            .call(JobSpec::fir(taps.clone(), x.clone()).authenticated())
            .expect("auth fir");
        assert_bits_eq(&auth.values, &cold_fir.values, &format!("auth fir round {round}"));
        assert_eq!(auth.check, Some(values_checksum(&auth.values)));
    }
    assert_eq!(
        cached.metrics.total_integrity_detections(),
        0,
        "MAC/Freivalds checks must pass on cache hits"
    );
    // Plain matmul missed once; the three auth matmuls share its entry
    // (Freivalds has no separate cached operand). The auth-FIR tap plane
    // lives in the authenticated partition: one miss, two hits.
    assert_eq!(cached.metrics.cache_hits(JobKind::MatmulHybrid), 3);
    assert_eq!(cached.metrics.cache_hits(JobKind::FirHybrid), 2);

    assert!(cached.shutdown().is_clean());
    assert!(cold.shutdown().is_clean());
}

#[test]
fn invalidation_forces_re_encode_and_never_serves_stale() {
    let coord = coordinator_with(32 << 20);
    let mut rng = Rng::new(71);
    let a = Dist::moderate().sample_vec(&mut rng, DIM * DIM);
    let b = Dist::moderate().sample_vec(&mut rng, DIM * DIM);
    let spec = || JobSpec::matmul(a.clone(), b.clone(), DIM);

    let first = coord.call(spec()).expect("first matmul");
    let _ = coord.call(spec()).expect("second matmul");
    assert_eq!(coord.metrics.cache_hits(JobKind::MatmulHybrid), 1);
    assert_eq!(coord.op_cache().unwrap().len(), 1);

    // Drop everything (registry rebuild / key rotation path): the next
    // job must re-encode, not resurrect the old entry.
    coord.invalidate_op_cache();
    assert!(coord.op_cache().unwrap().is_empty(), "invalidation empties the cache");

    let again = coord.call(spec()).expect("post-invalidation matmul");
    assert_bits_eq(&again.values, &first.values, "post-invalidation re-encode");
    assert_eq!(
        coord.metrics.cache_misses(JobKind::MatmulHybrid),
        2,
        "invalidation must force a fresh miss"
    );
    let after = coord.call(spec()).expect("re-cached matmul");
    assert_bits_eq(&after.values, &first.values, "re-cached serve");
    assert_eq!(coord.metrics.cache_hits(JobKind::MatmulHybrid), 2);

    assert!(coord.shutdown().is_clean());
}

#[test]
fn undersized_cache_bypasses_large_operands_without_corruption() {
    // A capacity smaller than one encoded plane: every lookup misses and
    // the built value is returned uncached — results stay exact and the
    // cache never grows.
    let tiny = coordinator_with(256);
    let cold = coordinator_with(0);
    let mut rng = Rng::new(73);
    let b = Dist::moderate().sample_vec(&mut rng, DIM * DIM);
    for round in 0..2 {
        let a = Dist::moderate().sample_vec(&mut rng, DIM * DIM);
        let hot = tiny
            .call(JobSpec::matmul(a.clone(), b.clone(), DIM))
            .expect("tiny-cache matmul");
        let reference = cold
            .call(JobSpec::matmul(a, b.clone(), DIM))
            .expect("cold matmul");
        assert_bits_eq(&hot.values, &reference.values, &format!("oversize round {round}"));
    }
    assert_eq!(tiny.metrics.cache_hits(JobKind::MatmulHybrid), 0, "nothing fits, nothing hits");
    assert_eq!(tiny.metrics.cache_misses(JobKind::MatmulHybrid), 2);
    assert!(tiny.op_cache().unwrap().is_empty(), "oversize operands are never admitted");

    assert!(tiny.shutdown().is_clean());
    assert!(cold.shutdown().is_clean());
}
