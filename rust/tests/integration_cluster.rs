//! Integration: the sharded cluster topology (`--features rpc`) end to
//! end, all in one process on ephemeral ports — a `ShardRouter` over
//! real worker `RpcServer`s. Pins the three cluster contracts:
//!
//! * **numerical transparency** — paper-tier results routed through the
//!   cluster are bit-identical to the in-process planar path,
//! * **failover** — killing a worker mid-stream loses zero accepted
//!   jobs (in-flight work is resubmitted to the survivors),
//! * **drain on membership change** — `remove_worker` fences the shard,
//!   hands its lanes to the survivors, and reports the handoff.
#![cfg(feature = "rpc")]

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::cluster::{RouterConfig, ShardRouter, WorkerSpec};
use hrfna::coordinator::router::ShapeBuckets;
use hrfna::coordinator::rpc::{RpcServer, RpcServerConfig};
use hrfna::coordinator::{
    Backend, ContextRegistry, Coordinator, CoordinatorConfig, Error, ExecMode, InProcess, JobSpec,
    Tier,
};
use hrfna::runtime::EngineHandle;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::Dist;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One in-process "worker process": an `InProcess` coordinator behind
/// its own `RpcServer` on an ephemeral port.
struct Worker {
    backend: Arc<InProcess>,
    server: RpcServer,
    spec: WorkerSpec,
}

fn coordinator() -> Coordinator {
    let engine = EngineHandle::spawn(None).expect("engine load");
    Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 1,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                capacity: 1024,
            },
            buckets: ShapeBuckets::default(),
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    )
}

fn spawn_worker(id: usize) -> Worker {
    let backend = Arc::new(InProcess::new(coordinator()));
    let server = RpcServer::bind(
        Arc::clone(&backend) as Arc<dyn Backend>,
        "127.0.0.1:0",
        RpcServerConfig::default(),
    )
    .expect("bind worker rpc server");
    let spec = WorkerSpec {
        id: format!("w{id}"),
        addr: server.local_addr().to_string(),
    };
    Worker { backend, server, spec }
}

fn start_router(workers: &[Worker]) -> ShardRouter {
    start_router_cfg(workers, RouterConfig::default())
}

fn start_router_cfg(workers: &[Worker], cfg: RouterConfig) -> ShardRouter {
    let specs: Vec<WorkerSpec> = workers.iter().map(|w| w.spec.clone()).collect();
    let router = ShardRouter::start(
        specs,
        RouterConfig {
            health_interval: Duration::from_millis(100),
            connect_wait: Duration::from_secs(2),
            ..cfg
        },
    )
    .expect("start shard router");
    assert_eq!(router.up_count(), workers.len(), "all workers must come up");
    router
}

/// Tolerant worker teardown: `Err(ShuttingDown)` means the router's
/// shutdown RPC already drained this backend.
fn stop_worker(w: Worker) {
    w.server.stop();
    if let Ok(d) = w.backend.shutdown() {
        assert_eq!(d.dropped, 0, "worker {} dropped jobs: {d}", w.spec.id);
    }
}

/// Mixed-lane traffic: both dot buckets × all three tiers, so six
/// hybrid lanes spread over the ring and every worker owns some.
fn lane_spread_spec(rng: &mut Rng, slot: usize) -> (JobSpec, f64, f64) {
    let n = if slot % 2 == 0 { 512 } else { 4096 };
    let x = Dist::moderate().sample_vec(rng, n);
    let y = Dist::moderate().sample_vec(rng, n);
    let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    let scale: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
    let spec = JobSpec::dot(x, y).tier(Tier::ALL[slot % Tier::ALL.len()]);
    (spec, truth, scale)
}

#[test]
fn paper_tier_results_through_router_bit_identical_to_in_process() {
    // The cluster must be numerically transparent: a job routed over two
    // socket hops onto a sharded fleet returns the *same bits* as the
    // same job served by one in-process planar coordinator.
    let local = InProcess::new(coordinator());
    let workers: Vec<Worker> = (0..2).map(spawn_worker).collect();
    let router = start_router(&workers);

    let mut rng = Rng::new(2028);
    for slot in 0..12usize {
        // Exact bucket sizes so admission pads nothing.
        let n = if slot % 2 == 0 { 512 } else { 4096 };
        let x = Dist::high_dynamic_range().sample_vec(&mut rng, n);
        let y = Dist::moderate().sample_vec(&mut rng, n);
        let routed = router
            .call(JobSpec::dot(x.clone(), y.clone()))
            .expect("routed paper dot");
        let direct = local.call(JobSpec::dot(x, y)).expect("local paper dot");
        assert_eq!(routed.tier, Tier::Paper);
        assert_eq!(
            routed.values[0].to_bits(),
            direct.values[0].to_bits(),
            "job {slot}: routed {} != in-process {}",
            routed.values[0],
            direct.values[0]
        );
    }

    let drain = router.shutdown().expect("router shutdown");
    assert!(drain.is_clean(), "unclean router drain: {drain}");
    for w in workers {
        stop_worker(w);
    }
    assert!(local.shutdown().expect("local shutdown").is_clean());
}

#[test]
fn coalesced_submits_stay_bit_identical_to_in_process() {
    // Coalescing is a framing optimization at the router edge: jobs that
    // ride one `submit_batch` frame must return the same bits as the
    // in-process planar path, and the batcher must actually form groups
    // (16 same-lane submits with max 4 → count-triggered flushes).
    let local = InProcess::new(coordinator());
    let workers: Vec<Worker> = (0..2).map(spawn_worker).collect();
    let router = start_router_cfg(
        &workers,
        RouterConfig {
            coalesce_window: Duration::from_millis(2),
            coalesce_max: 4,
            ..RouterConfig::default()
        },
    );

    let mut rng = Rng::new(515);
    let mut pending = Vec::new();
    for _ in 0..16usize {
        let x = Dist::moderate().sample_vec(&mut rng, 512);
        let y = Dist::moderate().sample_vec(&mut rng, 512);
        let ticket = router
            .submit(JobSpec::dot(x.clone(), y.clone()))
            .expect("coalesced submit accepted");
        pending.push((ticket, x, y));
    }
    for (slot, (ticket, x, y)) in pending.into_iter().enumerate() {
        let routed = router.wait(&ticket, Duration::from_secs(30)).expect("coalesced result");
        let direct = local.call(JobSpec::dot(x, y)).expect("local dot");
        assert_eq!(
            routed.values[0].to_bits(),
            direct.values[0].to_bits(),
            "job {slot}: coalesced {} != in-process {}",
            routed.values[0],
            direct.values[0]
        );
    }
    let text = router.metrics_text();
    assert!(text.contains("coalesce: window"), "coalesce line missing:\n{text}");
    assert!(!text.contains("flushes 0 "), "no groups ever flushed:\n{text}");

    // A partial group (below `coalesce_max`) must still be delivered by
    // the window-expiry flush, not stranded in staging.
    let x = Dist::moderate().sample_vec(&mut rng, 512);
    let y = Dist::moderate().sample_vec(&mut rng, 512);
    let lone = router.call(JobSpec::dot(x.clone(), y.clone())).expect("timer-flushed job");
    let direct = local.call(JobSpec::dot(x, y)).expect("local dot");
    assert_eq!(lone.values[0].to_bits(), direct.values[0].to_bits());

    let drain = router.shutdown().expect("router shutdown");
    assert!(drain.is_clean(), "unclean coalesced drain: {drain}");
    for w in workers {
        stop_worker(w);
    }
    assert!(local.shutdown().expect("local shutdown").is_clean());
}

#[test]
fn worker_loss_with_coalescing_loses_zero_jobs() {
    // The failover contract survives group framing: jobs that went out
    // inside one coalesced `submit_batch` are resubmitted as a group
    // when their worker dies mid-stream.
    let mut workers: Vec<Worker> = (0..2).map(spawn_worker).collect();
    let router = start_router_cfg(
        &workers,
        RouterConfig {
            coalesce_window: Duration::from_micros(500),
            coalesce_max: 4,
            ..RouterConfig::default()
        },
    );

    let mut rng = Rng::new(606);
    let mut pending = Vec::new();
    for slot in 0..36usize {
        let (spec, truth, scale) = lane_spread_spec(&mut rng, slot);
        let ticket = router.submit(spec).expect("cluster accepts the stream");
        pending.push((ticket, truth, scale));
    }
    let victim = workers.remove(1);
    let victim_backend = Arc::clone(&victim.backend);
    victim.server.stop(); // groups in flight on w1 are orphaned whole

    for (ticket, truth, scale) in pending {
        let r = router
            .wait(&ticket, Duration::from_secs(60))
            .expect("accepted job survives the worker loss");
        assert!(
            (r.values[0] - truth).abs() <= 1e-2 * scale.max(1e-300),
            "failover result off: {} vs {truth}",
            r.values[0]
        );
    }

    let drain = router.shutdown().expect("router shutdown");
    assert_eq!(drain.dropped, 0, "coalesced failover must not drop jobs: {drain}");
    for w in workers {
        stop_worker(w);
    }
    if let Ok(d) = victim_backend.shutdown() {
        assert_eq!(d.dropped, 0, "victim backend dropped jobs: {d}");
    }
}

#[test]
fn worker_loss_mid_stream_fails_over_with_zero_lost_jobs() {
    let mut workers: Vec<Worker> = (0..2).map(spawn_worker).collect();
    let router = start_router(&workers);

    // Fire a stream of accepted jobs across all six lanes, then kill one
    // worker while they are in flight.
    let mut rng = Rng::new(404);
    let mut pending = Vec::new();
    for slot in 0..36usize {
        let (spec, truth, scale) = lane_spread_spec(&mut rng, slot);
        let ticket = router.submit(spec).expect("cluster accepts the stream");
        pending.push((ticket, truth, scale));
    }
    let victim = workers.remove(1);
    let victim_backend = Arc::clone(&victim.backend);
    victim.server.stop(); // connections die mid-frame; jobs on w1 are orphaned

    // Every accepted job must still complete — the router resubmits the
    // orphans to the survivor (at-least-once; pure computation).
    for (ticket, truth, scale) in pending {
        let r = router
            .wait(&ticket, Duration::from_secs(60))
            .expect("accepted job survives the worker loss");
        assert!(
            (r.values[0] - truth).abs() <= 1e-2 * scale.max(1e-300),
            "failover result off: {} vs {truth}",
            r.values[0]
        );
    }

    // The monitor notices the dead shard.
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.up_count() != 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(router.up_count(), 1, "dead worker must leave the Up set");

    // New work keeps flowing to the survivor.
    let (spec, truth, scale) = lane_spread_spec(&mut rng, 1);
    let r = router.call(spec).expect("degraded fleet still serves");
    assert!((r.values[0] - truth).abs() <= 1e-2 * scale.max(1e-300));

    let drain = router.shutdown().expect("router shutdown");
    assert_eq!(drain.dropped, 0, "failover must not drop jobs: {drain}");
    for w in workers {
        stop_worker(w);
    }
    // The victim's backend outlived its socket; it drains clean locally.
    if let Ok(d) = victim_backend.shutdown() {
        assert_eq!(d.dropped, 0, "victim backend dropped jobs: {d}");
    }
}

#[test]
fn remove_worker_drains_and_survivors_take_over() {
    let workers: Vec<Worker> = (0..2).map(spawn_worker).collect();
    let router = start_router(&workers);

    let mut rng = Rng::new(77);
    for slot in 0..12usize {
        let (spec, truth, scale) = lane_spread_spec(&mut rng, slot);
        let r = router.call(spec).expect("pre-removal traffic");
        assert!((r.values[0] - truth).abs() <= 1e-2 * scale.max(1e-300));
    }

    // Fence w1 out: its lanes move to w0, the handoff is reported.
    let report = router.remove_worker("w1").expect("remove a live worker");
    assert_eq!(report.dropped, 0, "{report}");
    assert_eq!(router.up_count(), 1, "retired shard leaves the Up set");
    assert!(
        router.metrics_text().contains("(retired)"),
        "{}",
        router.metrics_text()
    );

    // The last worker is load-bearing: removing it is refused and the
    // fleet keeps serving.
    let err = router.remove_worker("w0").expect_err("last worker is protected");
    assert!(matches!(err, Error::Rejected(_)), "{err:?}");
    let err = router.remove_worker("w1").expect_err("already removed");
    assert!(matches!(err, Error::Rejected(_)), "{err:?}");

    for slot in 0..12usize {
        let (spec, truth, scale) = lane_spread_spec(&mut rng, slot);
        let r = router.call(spec).expect("post-removal traffic on the survivor");
        assert!((r.values[0] - truth).abs() <= 1e-2 * scale.max(1e-300));
    }

    let drain = router.shutdown().expect("router shutdown");
    assert!(drain.is_clean(), "unclean drain after removal: {drain}");
    for w in workers {
        stop_worker(w);
    }
}

#[test]
fn router_rejections_and_shutdown_are_typed() {
    let workers: Vec<Worker> = (0..1).map(spawn_worker).collect();
    let router = start_router(&workers);
    assert_eq!(router.label(), "shard-router");

    // A payload no lane bucket admits is rejected at the routing layer —
    // it never crosses the wire.
    let err = router
        .submit(JobSpec::dot(vec![0.0; 100_000], vec![0.0; 100_000]))
        .expect_err("oversize dot has no lane");
    assert!(matches!(err, Error::Rejected(_)), "{err:?}");

    let drain = router.shutdown().expect("router shutdown");
    assert!(drain.is_clean(), "{drain}");
    let err = router
        .submit(JobSpec::dot(vec![1.0; 512], vec![1.0; 512]))
        .expect_err("submits after shutdown are refused");
    assert_eq!(err, Error::ShuttingDown);
    for w in workers {
        stop_worker(w);
    }
}

#[test]
fn empty_and_unreachable_fleets_fail_with_typed_errors() {
    let err = ShardRouter::start(Vec::new(), RouterConfig::default())
        .err()
        .expect("empty fleet refused");
    assert!(matches!(err, Error::Rejected(_)), "{err:?}");

    // A fleet where nobody answers: Unavailable, not a hang (the
    // connect budget bounds the wait).
    let err = ShardRouter::start(
        vec![WorkerSpec { id: "w0".into(), addr: "127.0.0.1:1".into() }],
        RouterConfig {
            connect_wait: Duration::from_millis(200),
            ..RouterConfig::default()
        },
    )
    .err()
    .expect("unreachable fleet refused");
    assert!(matches!(err, Error::Unavailable(_)), "{err:?}");
}
