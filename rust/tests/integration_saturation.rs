//! Saturation and shutdown-drain integration: flooding the coordinator
//! past its bounded queues from many client threads must produce typed
//! `Overloaded` rejections (not OOM, not deadlock), every accepted job
//! must complete, and shutdown must drain queued work before joining the
//! workers — with the drain report accounting for every job.

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::{
    Backend, ContextRegistry, Coordinator, CoordinatorConfig, Error, ExecMode, InProcess, JobKind,
    JobSpec,
};
use hrfna::runtime::EngineHandle;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::Dist;
use std::sync::Arc;
use std::time::Duration;

fn coordinator(batch: BatchPolicy, workers_per_lane: usize) -> Coordinator {
    let engine = EngineHandle::spawn(None).expect("engine load");
    Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane,
            batch,
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    )
}

#[test]
fn flood_past_capacity_sheds_load_and_drains_clean() {
    // A long batching window holds jobs in the queue while the flood
    // arrives, so the capacity bound is hit deterministically: one lane,
    // one shard of capacity 16, 8 clients × 25 jobs = 200 offered.
    let coord = Arc::new(coordinator(
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(150),
            capacity: 16,
        },
        1,
    ));
    let mut rng = Rng::new(1);
    let x = Dist::moderate().sample_vec(&mut rng, 512);
    let y = Dist::moderate().sample_vec(&mut rng, 512);
    let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();

    let mut handles = Vec::new();
    for _ in 0..8 {
        let coord = Arc::clone(&coord);
        let (x, y) = (x.clone(), y.clone());
        handles.push(std::thread::spawn(move || {
            let mut accepted = Vec::new();
            let mut overloaded = 0usize;
            for _ in 0..25 {
                match coord
                    .submit(JobSpec::dot(x.clone(), y.clone()))
                {
                    Ok(rx) => accepted.push(rx),
                    Err(Error::Overloaded { capacity, .. }) => {
                        assert!(capacity > 0, "typed overload carries queue state");
                        overloaded += 1;
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
            (accepted, overloaded)
        }));
    }
    let mut receivers = Vec::new();
    let mut overloaded = 0;
    for h in handles {
        let (rxs, o) = h.join().unwrap();
        receivers.extend(rxs);
        overloaded += o;
    }
    assert!(
        overloaded > 0,
        "flood past a 16-deep queue must shed load with Overloaded"
    );
    assert_eq!(receivers.len() + overloaded, 200);

    // Every accepted job completes with a correct result — no deadlock,
    // no silent drop.
    for rx in receivers {
        let r = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("accepted job completes")
            .expect("accepted job succeeds");
        assert!((r.values[0] - truth).abs() <= 1e-6 * truth.abs().max(1.0));
    }
    let metrics = Arc::clone(&coord.metrics);
    let accepted = metrics.total_accepted();
    let rejected = metrics.total_rejected();
    assert_eq!(rejected as usize, overloaded);
    let coord = Arc::try_unwrap(coord).unwrap_or_else(|_| panic!("sole owner"));
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
    assert_eq!(drain.accepted, accepted);
    assert_eq!(drain.completed, accepted);
    assert_eq!(drain.rejected, rejected);
    assert_eq!(drain.dropped, 0);
}

#[test]
fn shutdown_drains_queued_jobs_before_joining() {
    // A 10 s batching window parks submitted jobs in the queues; calling
    // shutdown immediately must flush and execute them (drain before
    // join), not drop them.
    let coord = coordinator(
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(10),
            capacity: 64,
        },
        2,
    );
    let mut rng = Rng::new(5);
    let mut pending = Vec::new();
    let mut truths = Vec::new();
    for _ in 0..12 {
        let x = Dist::moderate().sample_vec(&mut rng, 300);
        let y = Dist::moderate().sample_vec(&mut rng, 300);
        truths.push(x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>());
        pending.push(
            coord
                .submit(JobSpec::dot(x, y))
                .unwrap(),
        );
    }
    let t0 = std::time::Instant::now();
    let drain = coord.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "shutdown must flush the batching window, not wait it out"
    );
    assert!(drain.is_clean(), "{drain}");
    assert_eq!(drain.accepted, 12);
    assert_eq!(drain.completed, 12);
    assert!(
        drain.drained > 0,
        "jobs were parked in the queue at shutdown: {drain}"
    );
    for (rx, truth) in pending.into_iter().zip(truths) {
        let r = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("drained job still delivers its result")
            .expect("drained job succeeds");
        assert!((r.values[0] - truth).abs() <= 1e-6 * truth.abs().max(1.0));
    }
}

#[test]
fn idle_shutdown_is_clean() {
    let coord = coordinator(BatchPolicy::default(), 1);
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
    assert_eq!(drain.drained, 0);
}

#[test]
fn open_loop_overload_is_bounded_and_recovers() {
    use hrfna::coordinator::open_loop;
    // The load generator drives the `Backend` seam, same as the RPC and
    // cluster edges; wrap the coordinator in the in-process adapter.
    let backend = InProcess::new(coordinator(
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            capacity: 8,
        },
        1,
    ));
    let mut rng = Rng::new(9);
    let x = Dist::moderate().sample_vec(&mut rng, 4096);
    let y = Dist::moderate().sample_vec(&mut rng, 4096);
    // Offer far beyond single-worker capacity; the bounded lane must shed
    // rather than queue without bound, and shed jobs must not break the
    // accepted ones.
    let report = open_loop(&backend, 300, 50_000.0, &|_, _| {
        JobSpec::dot(x.clone(), y.clone())
    });
    assert_eq!(report.offered, 300);
    assert_eq!(report.accepted + report.rejected, 300);
    assert_eq!(report.completed, report.accepted);
    let depth = backend
        .with_coordinator(|c| c.metrics.queue_depth(JobKind::DotHybrid))
        .expect("backend still live");
    assert!(depth <= 16, "queue depth bounded by capacity, got {depth}");
    let drain = backend.shutdown().expect("first shutdown");
    assert!(drain.is_clean(), "{drain}");
}
