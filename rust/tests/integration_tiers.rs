//! Integration: the precision-tier registry end to end — mixed-tier
//! traffic over one coordinator, per-tier metrics rows, bound-driven
//! escalation, the paper-tier bit-identity pin against the pre-refactor
//! single-context serving path, and two-tier concurrent saturation.

use hrfna::config::HrfnaConfig;
use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::hybrid_exec::{encode_dot_batch, planar_dot_results};
use hrfna::coordinator::{
    ContextRegistry, Coordinator, CoordinatorConfig, Error, ExecMode, JobKind, JobSpec, Tier,
};
use hrfna::hybrid::registry::{tier_rel_bound, MagnitudeEnvelope};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::runtime::EngineHandle;
use hrfna::util::prng::Rng;
use hrfna::workloads::dot::dot_product_encoded_scalar;
use hrfna::workloads::generators::Dist;
use hrfna::workloads::rk4::{rk4_final_state, Ode};
use std::sync::Arc;
use std::time::Duration;

fn coordinator_with(exec: ExecMode, batch: BatchPolicy, workers_per_lane: usize) -> Coordinator {
    let engine = EngineHandle::spawn(None).expect("engine load");
    Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane,
            batch,
            exec,
            ..CoordinatorConfig::default()
        },
    )
}

fn coordinator() -> Coordinator {
    coordinator_with(
        ExecMode::Planar,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        },
        2,
    )
}

#[test]
fn mixed_tier_traffic_serves_correctly_with_per_tier_rows() {
    let coord = Arc::new(coordinator());
    let mut handles = Vec::new();
    for t in 0..3u64 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(500 + t);
            for i in 0..12 {
                let tier = Tier::ALL[(t as usize + i) % 3];
                let n = 64 + rng.below(400) as usize;
                let x = Dist::moderate().sample_vec(&mut rng, n);
                let y = Dist::moderate().sample_vec(&mut rng, n);
                let want: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
                let scale: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
                let env = MagnitudeEnvelope::of_slices(&[&x, &y], n as u64, 0);
                let r = coord
                    .call(JobSpec::dot(x, y).tier(tier))
                    .expect("tiered dot");
                assert_eq!(r.tier, tier, "moderate dot must run on its requested tier");
                let budget = tier_rel_bound(coord.registry().cfg(tier), &env);
                assert!(
                    (r.values[0] - want).abs() <= budget * scale.max(1e-300),
                    "thread {t} job {i} tier {tier:?}: {} vs {want}",
                    r.values[0]
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Every tier served jobs, on its own metrics row.
    for tier in Tier::ALL {
        assert_eq!(
            coord.metrics.jobs_tier(JobKind::DotHybrid, tier),
            12,
            "{tier:?} row"
        );
    }
    assert_eq!(coord.metrics.jobs(JobKind::DotHybrid), 36);
    assert_eq!(coord.metrics.total_escalations(), 0);
    let table = coord.metrics_table().render();
    for tier in Tier::ALL {
        assert!(table.contains(&format!("dot/hrfna@{}", tier.label())), "{table}");
    }
    let coord = Arc::try_unwrap(coord).unwrap_or_else(|_| panic!("sole owner"));
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn tolerance_and_envelope_escalation_fire_and_are_counted() {
    let coord = coordinator();
    let mut rng = Rng::new(9);
    let x = Dist::moderate().sample_vec(&mut rng, 512);
    let y = Dist::moderate().sample_vec(&mut rng, 512);
    // A 1e-7 tolerance is below lo's √n·2^-17 budget and inside paper's.
    let r = coord
        .call(JobSpec::dot(x.clone(), y.clone()).tier(Tier::Lo).tolerance(1e-7))
        .expect("escalated dot");
    assert_eq!(r.tier, Tier::Paper);
    let want: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    assert!((r.values[0] - want).abs() <= 1e-6 * want.abs().max(1.0));
    assert_eq!(coord.metrics.escalations_tier(JobKind::DotHybrid, Tier::Paper), 1);
    // Subnormal-scale magnitudes overflow lo's ω=12 exponent range.
    let tiny = vec![f64::MIN_POSITIVE; 64];
    let r = coord
        .call(JobSpec::dot(tiny.clone(), tiny).tier(Tier::Lo))
        .expect("envelope-escalated dot");
    assert!(r.tier > Tier::Lo, "exponent-range overflow must leave lo");
    assert!(coord.metrics.total_escalations() >= 2);
    // A tolerance not even wide's bound covers is REJECTED with a typed
    // error, never silently served outside its stated tolerance.
    let err = coord
        .submit(JobSpec::dot(x.clone(), y.clone()).tolerance(1e-30))
        .expect_err("uncoverable tolerance must be rejected");
    assert!(matches!(err, Error::Rejected(_)), "{err}");
    assert!(err.to_string().contains("formal bound"), "{err}");
    // Escalations land in the table's `esc` column.
    let table = coord.metrics_table().render();
    assert!(table.contains("esc"));
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn paper_tier_bit_identical_to_pre_refactor_single_context_path() {
    // The registry refactor must not perturb the default serving path by
    // one bit: paper-tier results served through the registry equal what
    // the pre-refactor coordinator computed from its single
    // `HrfnaContext::paper_default()` — reproduced here by running the
    // same planar pipeline (block encode → lane dot → batched CRT) and
    // the scalar reference pipeline directly on a standalone context.
    let standalone = HrfnaContext::new(HrfnaConfig::paper_default());
    let mut rng = Rng::new(2027);
    let n = 512; // exact bucket size: admission pads nothing
    let jobs: Vec<(Vec<f64>, Vec<f64>)> = (0..6)
        .map(|_| {
            (
                Dist::high_dynamic_range().sample_vec(&mut rng, n),
                Dist::moderate().sample_vec(&mut rng, n),
            )
        })
        .collect();
    for exec in [ExecMode::Planar, ExecMode::Scalar] {
        let coord = coordinator_with(
            exec,
            BatchPolicy {
                max_batch: 3,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            2,
        );
        for (x, y) in &jobs {
            let r = coord
                .call(JobSpec::dot(x.clone(), y.clone()))
                .expect("paper dot");
            assert_eq!(r.tier, Tier::Paper);
            let want = match exec {
                ExecMode::Planar => {
                    let ex = encode_dot_batch(&[x.as_slice()], n, &standalone);
                    let ey = encode_dot_batch(&[y.as_slice()], n, &standalone);
                    planar_dot_results(&ex, &ey, &standalone)[0]
                }
                ExecMode::Scalar => {
                    let ex: Vec<Hrfna> =
                        x.iter().map(|&v| Hrfna::encode(v, &standalone)).collect();
                    let ey: Vec<Hrfna> =
                        y.iter().map(|&v| Hrfna::encode(v, &standalone)).collect();
                    dot_product_encoded_scalar::<Hrfna>(&ex, &ey, &standalone)
                        .decode(&standalone)
                }
            };
            assert_eq!(
                r.values[0].to_bits(),
                want.to_bits(),
                "{exec:?}: served {} != pre-refactor {want}",
                r.values[0]
            );
        }
        assert!(coord.shutdown().is_clean());
    }
}

#[test]
fn rk4_tier_results_match_the_tier_context_scalar_reference() {
    let coord = coordinator();
    let (mu, dt, steps) = (1.0, 0.01, 150u64);
    for tier in [Tier::Lo, Tier::Wide] {
        let y0 = vec![1.5, -0.5];
        let r = coord
            .call(JobSpec::rk4(y0.clone(), mu, dt, steps).tier(tier))
            .expect("tiered rk4");
        assert_eq!(r.tier, tier);
        // The planar batch mirrors the scalar ops exactly under the same
        // context, so the served result equals the tier's scalar
        // reference bit for bit.
        let ctx = coord.registry().get(tier);
        let want = rk4_final_state::<Hrfna>(&Ode::VanDerPol { mu }, &y0, dt, steps, &ctx);
        assert_eq!(r.values, want, "{tier:?}");
    }
    // Both tier contexts were actually constructed (and only on demand).
    assert!(coord.registry().peek(Tier::Lo).is_some());
    assert!(coord.registry().peek(Tier::Wide).is_some());
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn two_tier_concurrent_flood_sheds_per_lane_and_drains_clean() {
    // Saturation across tiers: flood the lo and wide dot lanes at once
    // past a 16-deep queue. Each lane sheds with a typed Overloaded that
    // names its tier, accepted jobs all complete, and the drain report
    // accounts for every job — the backpressure contract is per lane,
    // so one tier's flood cannot starve the other of its typed signal.
    let coord = Arc::new(coordinator_with(
        ExecMode::Planar,
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(150),
            capacity: 16,
        },
        1,
    ));
    let mut rng = Rng::new(31);
    let x = Dist::moderate().sample_vec(&mut rng, 512);
    let y = Dist::moderate().sample_vec(&mut rng, 512);
    let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    let scale: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
    let env = MagnitudeEnvelope::of_slices(&[&x, &y], 512, 0);
    let mut handles = Vec::new();
    for (tid, tier) in [Tier::Lo, Tier::Wide].into_iter().enumerate() {
        for _ in 0..4 {
            let coord = Arc::clone(&coord);
            let (x, y) = (x.clone(), y.clone());
            handles.push(std::thread::spawn(move || {
                let mut accepted = Vec::new();
                let mut overloaded = 0usize;
                for _ in 0..25 {
                    let spec = JobSpec::dot(x.clone(), y.clone()).tier(tier);
                    match coord.submit(spec) {
                        Ok(rx) => accepted.push(rx),
                        Err(Error::Overloaded { tier: t, capacity, .. }) => {
                            assert_eq!(t, tier, "overload names the flooded tier");
                            assert!(capacity > 0);
                            overloaded += 1;
                        }
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
                (tid, accepted, overloaded)
            }));
        }
    }
    let mut receivers = Vec::new();
    let mut shed = [0usize; 2];
    for h in handles {
        let (tid, rxs, o) = h.join().unwrap();
        receivers.extend(rxs);
        shed[tid] += o;
    }
    assert!(shed[0] > 0, "lo flood must shed");
    assert!(shed[1] > 0, "wide flood must shed");
    assert_eq!(receivers.len() + shed[0] + shed[1], 200);
    for rx in receivers {
        let r = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("accepted job completes")
            .expect("accepted job succeeds");
        // Each result stays within its own tier's a-priori budget
        // (lo's quantization is orders of magnitude coarser than wide's).
        let budget = tier_rel_bound(coord.registry().cfg(r.tier), &env);
        assert!(
            (r.values[0] - truth).abs() <= budget * scale,
            "{:?}: {} vs {truth}",
            r.tier,
            r.values[0]
        );
    }
    // Both tiers produced jobs on their own metric rows.
    assert!(coord.metrics.jobs_tier(JobKind::DotHybrid, Tier::Lo) > 0);
    assert!(coord.metrics.jobs_tier(JobKind::DotHybrid, Tier::Wide) > 0);
    assert_eq!(coord.metrics.jobs_tier(JobKind::DotHybrid, Tier::Paper), 0);
    let coord = Arc::try_unwrap(coord).unwrap_or_else(|_| panic!("sole owner"));
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}
