//! Integration: the full coordinator stack (admission → sharded bounded
//! queues → planar batch execution → bulk decode) serves correct results
//! under concurrency, on both the planar and scalar-reference datapaths.
//! Uses the backend the build selected (software executor by default; the
//! PJRT client with `--features xla` + `make artifacts`).

use hrfna::config::HrfnaConfig;
use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::{
    ContextRegistry, Coordinator, CoordinatorConfig, ExecMode, JobKind, JobSpec, Payload, Tier,
};
use hrfna::hybrid::HrfnaContext;
use hrfna::runtime::EngineHandle;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::Dist;
use hrfna::workloads::rk4::{rk4_final_state, Ode};
use std::sync::Arc;
use std::time::Duration;

fn coordinator_with(exec: ExecMode) -> Coordinator {
    let engine = EngineHandle::spawn(None).expect("engine load");
    Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            exec,
            ..CoordinatorConfig::default()
        },
    )
}

fn coordinator() -> Coordinator {
    coordinator_with(ExecMode::Planar)
}

#[test]
fn serves_correct_dot_products_both_lanes() {
    let coord = coordinator();
    let mut rng = Rng::new(3);
    for kind in [JobKind::DotHybrid, JobKind::DotF32] {
        for _ in 0..5 {
            let n = 64 + rng.below(1000) as usize;
            let x = Dist::moderate().sample_vec(&mut rng, n);
            let y = Dist::moderate().sample_vec(&mut rng, n);
            let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            let r = coord.call(JobSpec::new(kind, Payload::Dot { x, y })).unwrap();
            let tol = match kind {
                JobKind::DotHybrid => 1e-6 * truth.abs().max(1.0),
                _ => 1e-3 * truth.abs().max(1.0),
            };
            assert!(
                (r.values[0] - truth).abs() <= tol,
                "{kind:?}: got={} truth={truth}",
                r.values[0]
            );
            assert!(r.latency_us > 0.0);
            // The plain submit path is paper-tier by construction.
            assert_eq!(r.tier, Tier::Paper);
        }
    }
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn scalar_and_planar_paths_agree() {
    // The scalar reference datapath and the planar serving path must
    // produce results within the shared accuracy budget on identical
    // inputs (they round differently — per-element vs block exponents —
    // so agreement is to tolerance, not bit-exact).
    let mut rng = Rng::new(41);
    let x = Dist::moderate().sample_vec(&mut rng, 700);
    let y = Dist::moderate().sample_vec(&mut rng, 700);
    let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    let mut got = Vec::new();
    for exec in [ExecMode::Scalar, ExecMode::Planar] {
        let coord = coordinator_with(exec);
        let r = coord.call(JobSpec::dot(x.clone(), y.clone())).unwrap();
        got.push(r.values[0]);
        assert!(coord.shutdown().is_clean());
    }
    for v in &got {
        assert!((v - truth).abs() <= 1e-6 * truth.abs().max(1.0), "{v} vs {truth}");
    }
    assert!((got[0] - got[1]).abs() <= 2e-6 * truth.abs().max(1.0));
}

#[test]
fn serves_correct_matmul_hybrid() {
    let coord = coordinator();
    let mut rng = Rng::new(9);
    let dim = 64;
    let a = Dist::moderate().sample_vec(&mut rng, dim * dim);
    let b = Dist::moderate().sample_vec(&mut rng, dim * dim);
    let r = coord
        .call(JobSpec::matmul(a.clone(), b.clone(), dim))
        .unwrap();
    assert_eq!(r.values.len(), dim * dim);
    // Spot-check a few elements against f64.
    let mut rng2 = Rng::new(10);
    for _ in 0..20 {
        let i = rng2.below(dim as u64) as usize;
        let j = rng2.below(dim as u64) as usize;
        let mut truth = 0.0;
        for p in 0..dim {
            truth += a[i * dim + p] * b[p * dim + j];
        }
        assert!(
            (r.values[i * dim + j] - truth).abs() < 1e-6 * truth.abs().max(1.0),
            "({i},{j})"
        );
    }
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn serves_rk4_matching_scalar_reference() {
    let coord = coordinator();
    let ctx = HrfnaContext::new(HrfnaConfig::paper_default());
    let mut rng = Rng::new(77);
    let mut pending = Vec::new();
    let mut y0s = Vec::new();
    let (mu, dt, steps) = (1.0, 0.01, 120u64);
    for _ in 0..6 {
        let y0 = vec![rng.uniform(-1.5, 1.5), rng.uniform(-1.5, 1.5)];
        pending.push(coord.submit(JobSpec::rk4(y0.clone(), mu, dt, steps)).unwrap());
        y0s.push(y0);
    }
    for (rx, y0) in pending.into_iter().zip(&y0s) {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        // The planar batch mirrors the scalar ops exactly, so the served
        // result equals the scalar reference bit for bit.
        let want = rk4_final_state::<hrfna::hybrid::Hrfna>(
            &Ode::VanDerPol { mu },
            y0,
            dt,
            steps,
            &ctx,
        );
        assert_eq!(r.values, want);
    }
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn concurrent_mixed_load_all_complete() {
    let coord = Arc::new(coordinator());
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            let mut checked = 0;
            for i in 0..10 {
                let n = 128 + rng.below(512) as usize;
                let x = Dist::moderate().sample_vec(&mut rng, n);
                let y = Dist::moderate().sample_vec(&mut rng, n);
                let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
                let kind = if i % 2 == 0 {
                    JobKind::DotHybrid
                } else {
                    JobKind::DotF32
                };
                let r = coord.call(JobSpec::new(kind, Payload::Dot { x, y })).unwrap();
                assert!(
                    (r.values[0] - truth).abs() < 1e-3 * truth.abs().max(1.0),
                    "thread {t} job {i}"
                );
                checked += 1;
            }
            checked
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 40);
    assert_eq!(coord.metrics.total_jobs(), 40);
}

#[test]
fn admission_rejects_invalid_jobs() {
    let coord = coordinator();
    // Oversize dot.
    assert!(coord
        .submit(JobSpec::dot(vec![0.0; 100_000], vec![0.0; 100_000]))
        .is_err());
    // NaN operand.
    assert!(coord
        .submit(JobSpec::dot_f32(vec![f64::NAN; 4], vec![1.0; 4]))
        .is_err());
    // Wrong matmul dim.
    assert!(coord
        .submit(JobSpec::matmul(vec![0.0; 9], vec![0.0; 9], 3))
        .is_err());
    // RK4 over the step cap.
    assert!(coord
        .submit(JobSpec::rk4(vec![1.0, 0.0], 1.0, 0.01, u64::MAX))
        .is_err());
    assert!(coord.metrics.total_rejected() >= 4);
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}

#[test]
fn batching_coalesces_bursts() {
    let coord = coordinator();
    let mut rng = Rng::new(55);
    let mut rxs = Vec::new();
    for _ in 0..16 {
        let x = Dist::moderate().sample_vec(&mut rng, 256);
        let y = Dist::moderate().sample_vec(&mut rng, 256);
        rxs.push(coord.submit(JobSpec::dot_f32(x, y)).unwrap());
    }
    let mut max_batch = 0;
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        max_batch = max_batch.max(r.batch_size);
    }
    assert!(max_batch >= 2, "burst should produce batches, got {max_batch}");
    let drain = coord.shutdown();
    assert!(drain.is_clean(), "{drain}");
}
