//! Integration: the RPC serving edge (`--features rpc`) end to end —
//! golden wire-format fixtures pinning the frame encodings, property
//! tests over the unified error-code and serialization contracts, and a
//! real loopback server (an `RpcServer` over the [`Backend`] seam)
//! driven through the client library: submits, batches, quotas,
//! draining, and the clean-shutdown invariant.
#![cfg(feature = "rpc")]

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::error::WIRE_CODES;
use hrfna::coordinator::router::ShapeBuckets;
use hrfna::coordinator::rpc::{
    decode_payload, encode_payload, result_from_json, result_to_json, socket_closed_loop,
    spec_from_json, spec_to_json, wire, ConnMode, FrameReader, Json, QuotaConfig, Request,
    Response, ResponseBody, RpcClient, RpcServer, RpcServerConfig,
};
use hrfna::coordinator::{
    Backend, ContextRegistry, Coordinator, CoordinatorConfig, Error, ExecMode, InProcess, JobKind,
    JobResult, JobSpec, Payload, Tier,
};
use hrfna::runtime::EngineHandle;
use hrfna::util::proptest::check;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::{Dist, ServeMix};
use std::sync::Arc;
use std::time::Duration;

fn coordinator() -> Coordinator {
    let engine = EngineHandle::spawn(None).expect("engine load");
    Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                capacity: 1024,
            },
            buckets: ShapeBuckets { tiers: Tier::ALL.to_vec(), ..ShapeBuckets::default() },
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    )
}

/// Server + backend for one test, bound to an ephemeral port.
fn serve(quota: QuotaConfig) -> (Arc<InProcess>, RpcServer, String) {
    let backend = Arc::new(InProcess::new(coordinator()));
    let server = RpcServer::bind(
        Arc::clone(&backend) as Arc<dyn Backend>,
        "127.0.0.1:0",
        RpcServerConfig { quota, ..RpcServerConfig::default() },
    )
    .expect("bind rpc server");
    let addr = server.local_addr().to_string();
    (backend, server, addr)
}

/// Tear down server then backend, asserting the drain invariant.
fn teardown(backend: Arc<InProcess>, server: RpcServer) {
    server.stop();
    let drain = backend.shutdown().expect("first shutdown");
    assert!(drain.is_clean(), "unclean drain: {drain}");
}

fn fixture(name: &str) -> String {
    let path = format!("{}/tests/fixtures/rpc/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read fixture {path}: {e}"))
        .trim_end()
        .to_string()
}

// ---------------------------------------------------------------------
// Golden wire-format fixtures: committed frames are byte-for-byte what
// the encoders produce today. A diff here is a wire break.
// ---------------------------------------------------------------------

#[test]
fn golden_request_submit_dot() {
    let text = fixture("request_submit_dot.json");
    let spec = JobSpec::dot(vec![1.0, -2.5], vec![0.5, 4.0])
        .tier(Tier::Lo)
        .tolerance(0.001);
    let req = Request::new(1, "submit", spec_to_json(&spec));
    assert_eq!(req.to_json().encode(), text, "request encoding drifted from fixture");

    // Decode side: fixture → typed request → identical spec.
    let parsed = Request::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(parsed.method, "submit");
    let back = spec_from_json(&parsed.params).unwrap();
    assert_eq!(back.kind, JobKind::DotHybrid);
    assert_eq!(back.tier, Tier::Lo);
    assert_eq!(back.tolerance, Some(0.001));
    match back.payload {
        Payload::Dot { x, y } => {
            assert_eq!(x, vec![1.0, -2.5]);
            assert_eq!(y, vec![0.5, 4.0]);
        }
        other => panic!("wrong payload {other:?}"),
    }
}

#[test]
fn golden_request_submit_fir_authenticated() {
    let text = fixture("request_submit_fir.json");
    let spec = JobSpec::fir(vec![0.25, 0.5, 0.25], vec![1.0, 2.0, 3.0, 4.0]).authenticated();
    let req = Request::new(1, "submit", spec_to_json(&spec));
    assert_eq!(req.to_json().encode(), text, "fir request encoding drifted from fixture");

    let parsed = Request::from_json(&Json::parse(&text).unwrap()).unwrap();
    let back = spec_from_json(&parsed.params).unwrap();
    assert_eq!(back.kind, JobKind::FirHybrid);
    assert_eq!(back.tier, Tier::Paper);
    assert!(back.auth, "auth bit lost on decode");
    match back.payload {
        Payload::Fir { taps, x } => {
            assert_eq!(taps, vec![0.25, 0.5, 0.25]);
            assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
        }
        other => panic!("wrong payload {other:?}"),
    }
}

#[test]
fn golden_response_result() {
    let text = fixture("response_result.json");
    let result = JobResult {
        id: 7,
        kind: JobKind::DotHybrid,
        tier: Tier::Lo,
        values: vec![2.25],
        latency_us: 123.5,
        batch_size: 8,
        check: None,
    };
    let resp = Response::result(1, result_to_json(&result));
    assert_eq!(resp.to_json().encode(), text, "response encoding drifted from fixture");

    let parsed = Response::from_json(&Json::parse(&text).unwrap()).unwrap();
    match parsed.body {
        ResponseBody::Result(v) => {
            let r = result_from_json(&v).unwrap();
            assert_eq!(r.id, 7);
            assert_eq!(r.values, vec![2.25]);
            assert_eq!(r.batch_size, 8);
        }
        other => panic!("expected result, got {other:?}"),
    }
}

#[test]
fn golden_error_overloaded() {
    let text = fixture("error_overloaded.json");
    let err = Error::Overloaded {
        kind: JobKind::DotHybrid,
        tier: Tier::Paper,
        queued: 32,
        capacity: 32,
    };
    let resp = Response::error(2, err.clone());
    assert_eq!(resp.to_json().encode(), text, "error encoding drifted from fixture");

    let parsed = Response::from_json(&Json::parse(&text).unwrap()).unwrap();
    match parsed.body {
        ResponseBody::Error(e) => {
            assert_eq!(e, err, "decode rebuilds the identical typed error");
            assert_eq!(e.wire_code(), -32002);
            assert!(e.is_backpressure());
        }
        other => panic!("expected error, got {other:?}"),
    }
}

#[test]
fn golden_frames_survive_the_codec() {
    // Every fixture, framed and unframed, bytes preserved.
    for name in [
        "request_submit_dot.json",
        "request_submit_fir.json",
        "response_result.json",
        "error_overloaded.json",
    ] {
        let text = fixture(name);
        let mut wire = Vec::new();
        hrfna::coordinator::rpc::write_frame(&mut wire, text.as_bytes()).unwrap();
        let mut reader = FrameReader::default();
        let payload = reader
            .read_frame(&mut std::io::Cursor::new(wire), &|| false)
            .unwrap()
            .expect("one frame");
        assert_eq!(payload, text.as_bytes(), "{name} mangled by codec");
    }
}

// ---------------------------------------------------------------------
// Property tests: stable code mapping and serialization round trips.
// ---------------------------------------------------------------------

/// One randomized value of every error variant, paired with its pinned
/// wire code (table order = `WIRE_CODES` order).
fn arbitrary_error(rng: &mut Rng) -> (Error, i64, &'static str) {
    let kind = JobKind::ALL[rng.below(JobKind::ALL.len() as u64) as usize];
    let tier = Tier::ALL[rng.below(Tier::ALL.len() as u64) as usize];
    let msg = format!("reason {}", rng.below(1000));
    let i = rng.below(WIRE_CODES.len() as u64) as usize;
    let err = match WIRE_CODES[i].1 {
        "parse_error" => Error::Parse(msg),
        "invalid_request" => Error::InvalidRequest(msg),
        "method_not_found" => Error::MethodNotFound(msg),
        "invalid_params" => Error::InvalidParams(msg),
        "internal" => Error::Internal(msg),
        "rejected" => Error::Rejected(msg),
        "overloaded" => Error::Overloaded {
            kind,
            tier,
            queued: rng.below(1 << 20) as usize,
            capacity: rng.below(1 << 20) as usize,
        },
        "shutting_down" => Error::ShuttingDown,
        "rate_limited" => Error::RateLimited(msg),
        "too_many_in_flight" => Error::TooManyInFlight(msg),
        "unavailable" => Error::Unavailable(msg),
        "integrity_failure" => Error::IntegrityFailure(msg),
        other => panic!("unknown table label {other}"),
    };
    (err, WIRE_CODES[i].0, WIRE_CODES[i].1)
}

#[test]
fn every_error_variant_keeps_its_stable_code_across_the_wire() {
    check("error -> wire code -> error", |rng| {
        let (err, want_code, want_label) = arbitrary_error(rng);
        hrfna::prop_assert!(
            err.wire_code() == want_code,
            "{err:?} mapped to {} not {want_code}",
            err.wire_code()
        );
        hrfna::prop_assert!(err.code_label() == want_label, "label drifted for {err:?}");
        // The typed value survives the wire losslessly: encode the error
        // response, parse it back, identical enum value — the router-hop
        // contract (worker error → router → client, same bytes).
        let resp = Response::error(9, err.clone());
        let text = resp.to_json().encode();
        let back = Response::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        match back.body {
            ResponseBody::Error(e) => {
                hrfna::prop_assert!(e == err, "round trip changed {err:?} into {e:?}");
                hrfna::prop_assert!(
                    Response::error(9, e).to_json().encode() == text,
                    "re-encode after a hop drifted"
                );
            }
            _ => return Err("error response parsed as result".into()),
        }
        Ok(())
    });
}

#[test]
fn specs_and_results_round_trip_fuzzed() {
    check("spec/result wire round trip", |rng| {
        let kind = JobKind::ALL[rng.below(JobKind::ALL.len() as u64) as usize];
        let tier = Tier::ALL[rng.below(Tier::ALL.len() as u64) as usize];
        let n = 1 + rng.below(16) as usize;
        let dist = Dist::moderate();
        let payload = match kind {
            JobKind::DotHybrid | JobKind::DotF32 => Payload::Dot {
                x: dist.sample_vec(rng, n),
                y: dist.sample_vec(rng, n),
            },
            JobKind::MatmulHybrid | JobKind::MatmulF32 => Payload::Matmul {
                a: dist.sample_vec(rng, n * n),
                b: dist.sample_vec(rng, n * n),
                dim: n,
            },
            JobKind::Rk4Hybrid => Payload::Rk4 {
                y0: dist.sample_vec(rng, 2),
                mu: rng.uniform(0.1, 4.0),
                dt: rng.uniform(1e-4, 1e-2),
                steps: 1 + rng.below(256),
            },
            JobKind::FirHybrid => Payload::Fir {
                taps: dist.sample_vec(rng, 1 + rng.below(4) as usize),
                x: dist.sample_vec(rng, n),
            },
        };
        let mut spec = JobSpec { kind, payload, tier, tolerance: None, auth: false };
        if rng.below(2) == 1 {
            spec = spec.tolerance(rng.lognormal(-10.0, 2.0));
        }
        // Authentication is a spec bit and must survive the wire; it is
        // only ever requested for MAC-capable hybrid kinds.
        if kind.is_hybrid() && kind != JobKind::Rk4Hybrid && rng.below(2) == 1 {
            spec = spec.authenticated();
        }
        let text = spec_to_json(&spec).encode();
        let back = spec_from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        hrfna::prop_assert!(back.kind == spec.kind, "kind changed");
        hrfna::prop_assert!(back.tier == spec.tier, "tier changed");
        hrfna::prop_assert!(back.tolerance == spec.tolerance, "tolerance changed");
        hrfna::prop_assert!(back.auth == spec.auth, "auth bit changed");
        hrfna::prop_assert!(
            spec_to_json(&back).encode() == text,
            "spec re-encode not canonical"
        );

        let result = JobResult {
            id: rng.next_u64() >> 12,
            kind,
            tier,
            values: dist.sample_vec(rng, n),
            latency_us: rng.uniform(1.0, 1e6),
            batch_size: 1 + rng.below(64) as usize,
            // Full-width u64 checksums must survive the wire (hex string,
            // not a JSON number).
            check: if rng.below(2) == 1 { Some(rng.next_u64()) } else { None },
        };
        let rtext = result_to_json(&result).encode();
        let rback = result_from_json(&Json::parse(&rtext).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        hrfna::prop_assert!(rback.id == result.id, "result id changed");
        hrfna::prop_assert!(rback.values == result.values, "result values changed");
        hrfna::prop_assert!(rback.check == result.check, "result checksum changed");
        hrfna::prop_assert!(
            result_to_json(&rback).encode() == rtext,
            "result re-encode not canonical"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Loopback server: the real edge end to end.
// ---------------------------------------------------------------------

#[test]
fn loopback_submit_returns_correct_dot_product() {
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mut client = RpcClient::connect(&addr).expect("connect");
    client.ping().expect("ping");

    let mut rng = Rng::new(11);
    let n = 512;
    let x = Dist::moderate().sample_vec(&mut rng, n);
    let y = Dist::moderate().sample_vec(&mut rng, n);
    let expect: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    let spec = JobSpec::dot(x, y);
    let outcome = client.call(&spec).expect("transport ok");
    let result = outcome.expect("job accepted");
    assert_eq!(result.kind, JobKind::DotHybrid);
    assert_eq!(result.tier, Tier::Paper);
    assert_eq!(result.values.len(), 1);
    let rel = ((result.values[0] - expect) / expect.abs().max(1e-300)).abs();
    assert!(rel < 1e-9, "dot over the wire off by {rel:.3e}");

    teardown(backend, server);
}

#[test]
fn loopback_health_reports_label_and_depth() {
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mut client = RpcClient::connect(&addr).expect("connect");
    let (label, queued) = client.health().expect("health answered");
    assert_eq!(label, "in-process");
    assert!(queued >= 0, "depth gauge is a count");
    teardown(backend, server);
}

#[test]
fn loopback_pipelined_submits_come_back_out_of_order_safe() {
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mut client = RpcClient::connect(&addr).expect("connect");
    let mut rng = Rng::new(7);
    let dist = Dist::moderate();
    // Fire a pipeline of mixed-tier submits, collect in reverse order —
    // correlation by id must hold regardless of arrival order.
    let mix = ServeMix::default_mix();
    let mut fired = Vec::new();
    for i in 0..24usize {
        let spec = JobSpec::dot(dist.sample_vec(&mut rng, 512), dist.sample_vec(&mut rng, 512))
            .tier(mix.tier_for(i));
        fired.push((client.submit_spec(&spec).expect("fire"), spec.tier));
    }
    for (id, want_tier) in fired.into_iter().rev() {
        let outcome = client.wait_submit(id).expect("transport ok");
        let result = outcome.expect("job accepted");
        assert_eq!(result.tier, want_tier, "tier context followed the job");
    }
    teardown(backend, server);
}

#[test]
fn loopback_batch_mixes_results_and_typed_errors() {
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mut client = RpcClient::connect(&addr).expect("connect");
    let mut rng = Rng::new(3);
    let dist = Dist::moderate();
    let good = JobSpec::dot(dist.sample_vec(&mut rng, 512), dist.sample_vec(&mut rng, 512));
    // Mismatched operand lengths fail admission → a typed Rejected entry
    // in the same batch response as the good results.
    let bad = JobSpec::dot(dist.sample_vec(&mut rng, 512), dist.sample_vec(&mut rng, 100));
    let outcomes = client
        .submit_batch(&[good.clone(), bad, good])
        .expect("transport ok");
    assert_eq!(outcomes.len(), 3);
    assert!(outcomes[0].is_ok(), "first spec accepted");
    let err = outcomes[1].as_ref().err().expect("second spec rejected");
    assert!(matches!(err, Error::Rejected(_)), "got {err:?}");
    assert!(outcomes[2].is_ok(), "third spec accepted");
    teardown(backend, server);
}

#[test]
fn loopback_quotas_shed_with_typed_codes() {
    // In-flight cap of zero: every submit sheds with TooManyInFlight.
    let (backend, server, addr) = serve(QuotaConfig {
        max_inflight: 0,
        rate_per_s: 0.0,
        burst: 64.0,
    });
    let mut client = RpcClient::connect(&addr).expect("connect");
    let mut rng = Rng::new(5);
    let dist = Dist::moderate();
    let spec = JobSpec::dot(dist.sample_vec(&mut rng, 512), dist.sample_vec(&mut rng, 512));
    let outcome = client.call(&spec).expect("transport ok");
    let err = outcome.err().expect("shed");
    assert!(matches!(err, Error::TooManyInFlight(_)), "got {err:?}");
    assert_eq!(err.wire_code(), -32005);
    assert_eq!(server.wire_metrics().totals().inflight_limited(), 1);
    teardown(backend, server);

    // Token bucket with one token and a negligible refill: the first
    // submit passes, the second is RateLimited.
    let (backend, server, addr) = serve(QuotaConfig {
        max_inflight: 256,
        rate_per_s: 1e-6,
        burst: 1.0,
    });
    let mut client = RpcClient::connect(&addr).expect("connect");
    let first = client.call(&spec).expect("transport ok");
    assert!(first.is_ok(), "first submit inside the burst");
    let second = client.call(&spec).expect("transport ok");
    let err = second.err().expect("shed");
    assert!(matches!(err, Error::RateLimited(_)), "got {err:?}");
    assert_eq!(server.wire_metrics().totals().rate_limited(), 1);
    teardown(backend, server);
}

#[test]
fn loopback_protocol_errors_answer_with_stable_codes() {
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mut client = RpcClient::connect(&addr).expect("connect");

    // Unknown method.
    let resp = client.request("warp", Json::Null).expect("transport ok");
    match resp.body {
        ResponseBody::Error(e) => assert!(matches!(e, Error::MethodNotFound(_)), "got {e:?}"),
        other => panic!("expected MethodNotFound, got {other:?}"),
    }
    // Undecodable params.
    let resp = client.request("submit", Json::str("not a spec")).expect("transport ok");
    match resp.body {
        ResponseBody::Error(e) => assert!(matches!(e, Error::InvalidParams(_)), "got {e:?}"),
        other => panic!("expected InvalidParams, got {other:?}"),
    }
    // Malformed JSON in a well-formed frame: answered (id 0) with
    // Parse, and the connection stays usable.
    use std::io::Write as _;
    let mut raw = std::net::TcpStream::connect(&addr).expect("raw connect");
    let payload = b"{this is not json";
    let mut frame = Vec::new();
    frame.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    frame.extend_from_slice(payload);
    raw.write_all(&frame).expect("send garbage");
    let mut reader = FrameReader::default();
    let answer = reader
        .read_frame(&mut raw, &|| false)
        .expect("read error response")
        .expect("server answered");
    let parsed = Response::from_json(&Json::parse(std::str::from_utf8(&answer).unwrap()).unwrap())
        .unwrap();
    assert_eq!(parsed.id, 0);
    match parsed.body {
        ResponseBody::Error(e) => {
            assert!(matches!(e, Error::Parse(_)), "got {e:?}");
            assert_eq!(e.wire_code(), -32700);
        }
        other => panic!("expected Parse, got {other:?}"),
    }
    assert!(server.wire_metrics().protocol_errors() >= 1);
    client.ping().expect("first connection still healthy");
    teardown(backend, server);
}

#[test]
fn loopback_drain_rejects_new_work_with_shutting_down() {
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mut client = RpcClient::connect(&addr).expect("connect");
    let mut rng = Rng::new(9);
    let dist = Dist::moderate();
    let spec = JobSpec::dot(dist.sample_vec(&mut rng, 512), dist.sample_vec(&mut rng, 512));
    assert!(client.call(&spec).expect("transport ok").is_ok());
    client.shutdown_server().expect("shutdown acknowledged");
    assert!(server.shutdown_requested());
    let outcome = client.call(&spec).expect("transport ok");
    assert_eq!(outcome.err().expect("shed"), Error::ShuttingDown);
    teardown(backend, server);
}

#[test]
fn socket_load_generator_round_trips_mixed_tier_traffic() {
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mix = ServeMix::default_mix();
    let make = |c: u64, i: usize| -> JobSpec {
        let (_, mut rng) = mix.request_rng(c + 1, i);
        JobSpec::dot(
            mix.dist.sample_vec(&mut rng, mix.dot_n),
            mix.dist.sample_vec(&mut rng, mix.dot_n),
        )
        .tier(mix.tier_for(i))
    };
    for mode in [ConnMode::Persistent, ConnMode::PerJob] {
        let report = socket_closed_loop(&addr, 3, 10, 4, mode, &make);
        assert_eq!(report.offered, 30, "{mode:?}");
        assert_eq!(report.completed, 30, "{mode:?} lost jobs");
        assert_eq!(report.rejected, 0, "{mode:?} shed jobs");
        assert!(report.latency_us.is_some());
    }
    let wire = Arc::clone(server.wire_metrics());
    // 3 persistent connections plus 30 per-job connections.
    assert!(wire.conns_opened() >= 33);
    assert_eq!(wire.totals().results(), 60);
    teardown(backend, server);
}

// ---------------------------------------------------------------------
// Binary wire payloads: golden envelopes, hello negotiation, and
// mixed-encoding interop. The binary framing is a transport
// optimization, never a numerical path — results must be bit-identical
// across encodings and against in-process execution.
// ---------------------------------------------------------------------

fn fixture_bytes(name: &str) -> Vec<u8> {
    let path = format!("{}/tests/fixtures/rpc/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read(&path).unwrap_or_else(|e| panic!("read fixture {path}: {e}"))
}

#[test]
fn golden_binary_request_submit_dot() {
    let bytes = fixture_bytes("request_submit_dot_bin.bin");
    let x = vec![1.0, -2.5, 0.5, 4.0, 123.5, -0.25, 2.25, 8.0];
    let y = vec![0.5, 4.0, 1.0, -2.5, 0.25, 16.0, -0.125, 2.0];
    let spec = JobSpec::dot(x.clone(), y.clone()).tier(Tier::Lo).tolerance(0.001);
    let req = Request::new(1, "submit", spec_to_json(&spec)).to_json();
    assert!(wire::is_binary(&bytes), "fixture carries the magic discriminator");
    assert_eq!(
        encode_payload(&req, true),
        bytes,
        "binary request encoding drifted from fixture"
    );

    // Decode side: fixture bytes → the identical parse tree the JSON
    // rendering produces → the identical spec, operands bit for bit.
    let tree = decode_payload(&bytes).expect("decode fixture");
    assert_eq!(tree, req, "decoded tree differs from the JSON rendering");
    let back = spec_from_json(&Request::from_json(&tree).unwrap().params).unwrap();
    assert_eq!(back.tier, Tier::Lo);
    assert_eq!(back.tolerance, Some(0.001));
    match back.payload {
        Payload::Dot { x: bx, y: by } => {
            assert_eq!(bx, x);
            assert_eq!(by, y);
        }
        other => panic!("wrong payload {other:?}"),
    }
}

#[test]
fn golden_binary_request_fir_authenticated() {
    let bytes = fixture_bytes("request_submit_fir_bin.bin");
    let taps = vec![0.25, 0.5, 0.25, 0.125, -0.125, 0.0625, -0.0625, 0.5];
    let x: Vec<f64> = (1..=12).map(f64::from).collect();
    let spec = JobSpec::fir(taps.clone(), x.clone()).authenticated();
    let req = Request::new(1, "submit", spec_to_json(&spec)).to_json();
    assert_eq!(
        encode_payload(&req, true),
        bytes,
        "binary fir request encoding drifted from fixture"
    );

    let tree = decode_payload(&bytes).expect("decode fixture");
    assert_eq!(tree, req, "decoded tree differs from the JSON rendering");
    let back = spec_from_json(&Request::from_json(&tree).unwrap().params).unwrap();
    assert!(back.auth, "auth bit lost in the binary envelope");
    match back.payload {
        Payload::Fir { taps: bt, x: bx } => {
            assert_eq!(bt, taps);
            assert_eq!(bx, x);
        }
        other => panic!("wrong payload {other:?}"),
    }
}

#[test]
fn golden_binary_response_result() {
    let bytes = fixture_bytes("response_result_bin.bin");
    let values = vec![2.25, -1.5, 0.5, 3.0, -0.125, 7.0, 0.75, -4.0];
    let result = JobResult {
        id: 7,
        kind: JobKind::DotHybrid,
        tier: Tier::Lo,
        values: values.clone(),
        latency_us: 123.5,
        batch_size: 8,
        check: None,
    };
    let resp = Response::result(1, result_to_json(&result)).to_json();
    assert_eq!(
        encode_payload(&resp, true),
        bytes,
        "binary response encoding drifted from fixture"
    );

    let tree = decode_payload(&bytes).expect("decode fixture");
    assert_eq!(tree, resp, "decoded tree differs from the JSON rendering");
    match Response::from_json(&tree).unwrap().body {
        ResponseBody::Result(v) => {
            let r = result_from_json(&v).unwrap();
            assert_eq!(r.id, 7);
            assert_eq!(r.values, values);
            assert_eq!(r.batch_size, 8);
        }
        other => panic!("expected result, got {other:?}"),
    }
}

#[test]
fn golden_binary_envelopes_survive_the_codec() {
    for name in [
        "request_submit_dot_bin.bin",
        "request_submit_fir_bin.bin",
        "response_result_bin.bin",
    ] {
        let bytes = fixture_bytes(name);
        let mut framed = Vec::new();
        hrfna::coordinator::rpc::write_frame(&mut framed, &bytes).unwrap();
        let mut reader = FrameReader::default();
        let payload = reader
            .read_frame(&mut std::io::Cursor::new(framed), &|| false)
            .unwrap()
            .expect("one frame");
        assert_eq!(payload, bytes, "{name} mangled by codec");
        assert!(wire::is_binary(&payload), "{name} lost its discriminator");
    }
}

#[test]
fn loopback_binary_results_bit_identical_to_json_and_in_process() {
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mut bin = RpcClient::connect(&addr).expect("connect binary client");
    assert!(bin.negotiate_binary().expect("hello answered"), "server grants bin1");
    assert!(bin.binary());
    let mut json = RpcClient::connect(&addr).expect("connect json client");
    assert!(!json.binary(), "un-negotiated connections stay pure JSON");

    let mut rng = Rng::new(23);
    let dist = Dist::moderate();
    for tier in Tier::ALL {
        let spec =
            JobSpec::dot(dist.sample_vec(&mut rng, 512), dist.sample_vec(&mut rng, 512)).tier(tier);
        let via_bin = bin.call(&spec).expect("transport ok").expect("accepted");
        let via_json = json.call(&spec).expect("transport ok").expect("accepted");
        let ticket = backend.submit(spec.clone()).expect("in-process admit");
        let direct = backend.wait(&ticket, Duration::from_secs(30)).expect("in-process result");
        for (i, ((b, j), d)) in
            via_bin.values.iter().zip(&via_json.values).zip(&direct.values).enumerate()
        {
            assert_eq!(b.to_bits(), j.to_bits(), "{tier:?} element {i}: binary vs json");
            assert_eq!(b.to_bits(), d.to_bits(), "{tier:?} element {i}: binary vs in-process");
        }
    }

    // An authenticated job rides the same binary envelope: values and the
    // MAC-backed checksum must agree with the JSON path exactly.
    let taps = vec![0.25, 0.5, 0.25, 0.125, -0.125, 0.0625, -0.0625, 0.5];
    let x = dist.sample_vec(&mut rng, 96);
    let spec = JobSpec::fir(taps, x).authenticated();
    let via_bin = bin.call(&spec).expect("transport ok").expect("accepted");
    let via_json = json.call(&spec).expect("transport ok").expect("accepted");
    for (i, (b, j)) in via_bin.values.iter().zip(&via_json.values).enumerate() {
        assert_eq!(b.to_bits(), j.to_bits(), "auth fir element {i}");
    }
    assert!(via_bin.check.is_some(), "authenticated result carries its checksum");
    assert_eq!(via_bin.check, via_json.check, "checksum differs across encodings");

    // The binary traffic actually happened, and only on the negotiated
    // connection: binary counters are a strict subset of the totals.
    let totals = server.wire_metrics().totals();
    assert!(totals.bin_frames_in() > 0, "no binary requests seen");
    assert!(totals.bin_frames_out() > 0, "no binary responses sent");
    assert!(totals.bin_frames_in() < totals.frames_in());
    assert!(totals.bin_bytes_out() < totals.bytes_out());
    assert_eq!(server.wire_metrics().protocol_errors(), 0, "mixed encodings, zero errors");
    teardown(backend, server);
}

#[test]
fn server_accepts_binary_requests_without_negotiation_and_answers_json() {
    // A new client talking to a server that never granted `bin1` on this
    // connection: binary *requests* are self-describing (magic byte), so
    // the server decodes them anyway — but keeps its responses JSON.
    let (backend, server, addr) = serve(QuotaConfig::default());
    let mut rng = Rng::new(29);
    let dist = Dist::moderate();
    let spec = JobSpec::dot(dist.sample_vec(&mut rng, 512), dist.sample_vec(&mut rng, 512));
    let req = Request::new(41, "submit", spec_to_json(&spec)).to_json();
    let payload = encode_payload(&req, true);
    assert!(wire::is_binary(&payload), "bulk operands actually went binary");

    let mut raw = std::net::TcpStream::connect(&addr).expect("raw connect");
    hrfna::coordinator::rpc::write_frame(&mut raw, &payload).expect("send binary submit");
    let mut reader = FrameReader::default();
    let answer = reader
        .read_frame(&mut raw, &|| false)
        .expect("read response")
        .expect("server answered");
    assert!(!wire::is_binary(&answer), "responses stay JSON until hello grants bin1");
    let resp =
        Response::from_json(&Json::parse(std::str::from_utf8(&answer).unwrap()).unwrap()).unwrap();
    assert_eq!(resp.id, 41);
    let result = match resp.body {
        ResponseBody::Result(v) => result_from_json(&v).unwrap(),
        other => panic!("expected result, got {other:?}"),
    };

    // Bit-identical to the same spec over a plain JSON connection.
    let mut client = RpcClient::connect(&addr).expect("connect");
    let via_json = client.call(&spec).expect("transport ok").expect("accepted");
    for (i, (b, j)) in result.values.iter().zip(&via_json.values).enumerate() {
        assert_eq!(b.to_bits(), j.to_bits(), "element {i}: binary request vs json");
    }
    assert_eq!(server.wire_metrics().protocol_errors(), 0);
    teardown(backend, server);
}

#[test]
fn negotiation_falls_back_to_json_against_a_server_without_hello() {
    use std::io::Write as _;
    // Stub "old server": answers the capability handshake with
    // MethodNotFound, the pre-binary protocol's reply to any unknown
    // method. The client must treat that as "no capabilities" and stay
    // in JSON mode — not as a transport error.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind stub");
    let addr = listener.local_addr().unwrap().to_string();
    let stub = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().expect("accept");
        let mut reader = FrameReader::default();
        let payload = reader
            .read_frame(&mut conn, &|| false)
            .expect("read hello")
            .expect("one frame");
        let req = Request::from_json(
            &Json::parse(std::str::from_utf8(&payload).unwrap()).unwrap(),
        )
        .unwrap();
        assert_eq!(req.method, "hello");
        let resp = Response::error(req.id, Error::MethodNotFound("hello".into()));
        let mut out = Vec::new();
        hrfna::coordinator::rpc::write_frame(&mut out, resp.to_json().encode().as_bytes())
            .unwrap();
        conn.write_all(&out).expect("answer hello");
    });
    let mut client = RpcClient::connect(&addr).expect("connect stub");
    assert!(
        !client.negotiate_binary().expect("fallback is not an error"),
        "old server grants nothing"
    );
    assert!(!client.binary(), "client stays in JSON mode against an old server");
    stub.join().unwrap();
}

// ---------------------------------------------------------------------
// The deprecated shims still compile and agree with the new surface.
// ---------------------------------------------------------------------

#[test]
#[allow(deprecated)]
fn deprecated_shims_agree_with_the_unified_surface() {
    use hrfna::coordinator::rpc::code_for_submit_error;
    use hrfna::coordinator::SubmitError;
    let e: SubmitError = Error::ShuttingDown;
    assert_eq!(code_for_submit_error(&e), e.wire_code());
    let spec = JobSpec::dot(vec![1.0], vec![1.0])
        .with_tier(Tier::Wide)
        .with_tolerance(1e-7);
    assert_eq!(spec.tier, Tier::Wide);
    assert_eq!(spec.tolerance, Some(1e-7));
}
