//! CI bench-regression gate: compares a current bench run's
//! `BENCH_*.json` records against committed baselines and exits nonzero
//! when jobs/sec or ns/op regressed beyond the tolerance — the measured
//! planar-serving speedup is a protected invariant, not a one-off number.
//!
//! Usage:
//!   bench_gate --baseline <file-or-dir> --current <file-or-dir> [--tolerance 0.20] [--strict]
//!
//! With directories, every `BENCH_*.json` in the baseline dir must exist
//! in the current dir and pass record-by-record. Refresh a baseline by
//! re-running the bench and committing the new JSON.
//!
//! By default, records the current run emits that the baseline does not
//! know are accepted with a warning (so a bench can grow records before
//! its baseline lands). `--strict` turns those into failures: every
//! measured record must have a committed baseline, which is what CI
//! runs — an unprotected record can't silently ride for months.

use hrfna::util::bench::{gate_records, new_record_names, read_json, GateViolation};
use hrfna::util::cli::Args;
use std::path::{Path, PathBuf};

/// Baseline/current file pairs to compare.
fn collect_pairs(baseline: &Path, current: &Path) -> Result<Vec<(PathBuf, PathBuf)>, String> {
    if baseline.is_file() {
        return Ok(vec![(baseline.to_path_buf(), current.to_path_buf())]);
    }
    if !baseline.is_dir() {
        return Err(format!("baseline path {} not found", baseline.display()));
    }
    let mut pairs = Vec::new();
    let mut names: Vec<PathBuf> = std::fs::read_dir(baseline)
        .map_err(|e| format!("read {}: {e}", baseline.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| {
            p.extension().map(|x| x == "json").unwrap_or(false)
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("BENCH_"))
                    .unwrap_or(false)
        })
        .collect();
    names.sort();
    for base in names {
        let file = base.file_name().expect("bench file name").to_owned();
        pairs.push((base, current.join(file)));
    }
    if pairs.is_empty() {
        return Err(format!("no BENCH_*.json baselines in {}", baseline.display()));
    }
    Ok(pairs)
}

fn main() {
    let args = Args::from_env();
    let baseline = args.str_or("baseline", "ci/baselines");
    let current = args.str_or("current", ".");
    let tolerance: f64 = args.parse_or("tolerance", 0.20);
    let strict = args.flag("strict");

    let pairs = match collect_pairs(Path::new(&baseline), Path::new(&current)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            std::process::exit(2);
        }
    };

    let mut failed = false;
    for (base_path, cur_path) in pairs {
        let base = match read_json(base_path.to_str().unwrap_or_default()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("bench_gate: cannot read baseline {}: {e}", base_path.display());
                failed = true;
                continue;
            }
        };
        let cur = match read_json(cur_path.to_str().unwrap_or_default()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!(
                    "bench_gate: current run missing {} ({e}) — did the bench run?",
                    cur_path.display()
                );
                failed = true;
                continue;
            }
        };
        if base.is_empty() {
            // A baseline that parses to zero records would make the gate
            // vacuously green — name the file and fail instead.
            eprintln!(
                "bench_gate: baseline {} contains no records — refusing a vacuous pass",
                base_path.display()
            );
            failed = true;
            continue;
        }
        let violations: Vec<GateViolation> = gate_records(&base, &cur, tolerance);
        println!(
            "bench_gate: {} vs {} — {} baseline records, {} violations (tolerance {:.0}%)",
            cur_path.display(),
            base_path.display(),
            base.len(),
            violations.len(),
            tolerance * 100.0
        );
        // Every baseline record missing from the measured run is a named
        // MISSING violation via gate_records (never a silent skip); the
        // converse — records the bench emits that the baseline does not
        // know — warns by default and fails under --strict.
        for v in &violations {
            println!("  {}", v.line());
        }
        for name in new_record_names(&base, &cur) {
            if strict {
                println!(
                    "  FAIL new    {name:<40} (no committed baseline — commit it to {} )",
                    base_path.display()
                );
                failed = true;
            } else {
                println!(
                    "  WARN new    {name:<40} (absent from baseline; accepted — refresh {} to protect it)",
                    base_path.display()
                );
            }
        }
        failed |= !violations.is_empty();
    }
    if failed {
        eprintln!("bench_gate: FAILED — perf regressed beyond tolerance (or records vanished)");
        std::process::exit(1);
    }
    println!("bench_gate: OK");
}
