"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the XLA
the published ``xla`` 0.1.6 crate binds) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import GRAPHS


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, (fn, example_args) in sorted(GRAPHS.items()):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        args_desc = ";".join(
            f"{a.dtype}{list(a.shape)}" for a in example_args
        )
        manifest_lines.append(f"{name} {name}.hlo.txt {args_desc}")
        print(f"  {name}: {len(text)} chars, args {args_desc}")
    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest}")
    return manifest_lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    lower_all(args.out)


if __name__ == "__main__":
    main()
