"""Pallas kernels: elementwise residue-channel modular multiply / add.

These are the paper's Definition 2 (element-wise residue multiplication,
r_Z,i = r_X,i * r_Y,i mod m_i) and the synchronized-addition residue step
(r_Z,i = r_X,i + r_Y,i mod m_i) as data-parallel maps over arrays of hybrid
values: inputs are (k, n) — n independent HRFNA values, one residue row per
channel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Perf (§Perf L1): one grid step per channel at the AOT bucket width.
DEFAULT_BLOCK_N = 4096


def _modmul_kernel(x_ref, y_ref, m_ref, o_ref):
    m = m_ref[0]
    o_ref[0, :] = (x_ref[0, :] * y_ref[0, :]) % m


def _modadd_kernel(x_ref, y_ref, m_ref, o_ref):
    m = m_ref[0]
    o_ref[0, :] = (x_ref[0, :] + y_ref[0, :]) % m


def _launch(kernel, x, y, m, block_n):
    k, n = x.shape
    block_n = min(block_n, n)
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    grid = (k, n // block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.int64),
        interpret=True,
    )(x, y, m)


@functools.partial(jax.jit, static_argnames=("block_n",))
def rns_modmul(x, y, m, *, block_n: int = DEFAULT_BLOCK_N):
    """Elementwise (x * y) mod m per channel; x, y: int64[k, n], m: int64[k]."""
    return _launch(_modmul_kernel, x, y, m, block_n)


@functools.partial(jax.jit, static_argnames=("block_n",))
def rns_modadd(x, y, m, *, block_n: int = DEFAULT_BLOCK_N):
    """Elementwise (x + y) mod m per channel; x, y: int64[k, n], m: int64[k]."""
    return _launch(_modadd_kernel, x, y, m, block_n)
