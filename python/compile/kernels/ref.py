"""Pure-jnp (and exact-python) oracles for the Layer-1 Pallas kernels.

Two tiers:
  * ``ref_*``     — pure jnp, same int64 overflow discipline, used as the
                    primary allclose target in pytest.
  * ``exact_*``   — arbitrary-precision Python ints (no overflow at all),
                    the ground truth the jnp oracles are themselves checked
                    against in the hypothesis sweeps.
"""

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# jnp oracles
# ---------------------------------------------------------------------------

def ref_dot(x, y, m):
    """out[i] = sum_j x[i,j]*y[i,j] mod m[i], chunked to stay exact in int64."""
    k, n = x.shape
    acc = jnp.zeros((k,), dtype=jnp.int64)
    chunk = 4096  # 2^32 * 2^12 = 2^44 < 2^63
    for s in range(0, n, chunk):
        prod = x[:, s:s + chunk] * y[:, s:s + chunk]
        acc = (acc + jnp.sum(prod % m[:, None], axis=1)) % m
    return acc


def ref_matmul(x, y, m):
    """out[i] = x[i] @ y[i] mod m[i]; contraction exact in int64 (K < 2^31)."""
    out = jnp.einsum("ijk,ikl->ijl", x, y)
    return out % m[:, None, None]


def ref_modmul(x, y, m):
    return (x * y) % m[:, None]


def ref_modadd(x, y, m):
    return (x + y) % m[:, None]


# ---------------------------------------------------------------------------
# exact python-int oracles (ground truth for hypothesis sweeps)
# ---------------------------------------------------------------------------

def exact_dot(x, y, m):
    x = np.asarray(x, dtype=object)
    y = np.asarray(y, dtype=object)
    k, n = x.shape
    out = []
    for i in range(k):
        acc = 0
        mi = int(m[i])
        for j in range(n):
            acc = (acc + int(x[i, j]) * int(y[i, j])) % mi
        out.append(acc)
    return np.array(out, dtype=np.int64)


def exact_matmul(x, y, m):
    k, mm, kk = x.shape
    _, _, nn = y.shape
    out = np.zeros((k, mm, nn), dtype=np.int64)
    for i in range(k):
        mi = int(m[i])
        xi = x[i].astype(object)
        yi = y[i].astype(object)
        out[i] = np.asarray((xi @ yi) % mi, dtype=np.int64)
    return out
