"""Pallas kernel: per-channel modular dense matmul (HRFNA matrix kernel,
paper §IV-E: matrix multiplication as composed hybrid dot products).

Given residue-encoded matrices ``x: (k, M, K)`` and ``y: (k, K, N)`` and the
modulus vector ``m: (k,)``, compute per channel

    out[i] = (x[i] @ y[i]) mod m[i]

The channel index is the leading grid dimension (carry-free lanes are
embarrassingly parallel); the contraction is tiled along K with one deferred
modular reduction per K-block, mirroring rns_dot's overflow discipline:
residues < 2^16 -> products < 2^32; a K-block of block_k products sums to
< 2^32 * block_k per output element, safe in int64 for block_k <= 2^31.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 128


def _mm_kernel(x_ref, y_ref, m_ref, o_ref):
    kk = pl.program_id(1)
    m = m_ref[0]

    x = x_ref[0]  # (M, block_k)
    y = y_ref[0]  # (block_k, N)
    part = jax.lax.dot_general(
        x, y,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int64,
    ) % m

    @pl.when(kk == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    o_ref[0] = (o_ref[0] + part) % m


@functools.partial(jax.jit, static_argnames=("block_k",))
def rns_matmul(x, y, m, *, block_k: int = DEFAULT_BLOCK_K):
    """Residue-domain matmul over k parallel channels.

    Args:
      x: int64[k, M, K] residues in [0, m[i]).
      y: int64[k, K, N] residues in [0, m[i]).
      m: int64[k] moduli (< 2^16).
      block_k: tile along the contraction; K must be a multiple.

    Returns:
      int64[k, M, N] per-channel product residues.
    """
    k, mm, kdim = x.shape
    _, _, nn = y.shape
    block_k = min(block_k, kdim)
    if kdim % block_k != 0:
        raise ValueError(f"K={kdim} must be a multiple of block_k={block_k}")
    grid = (k, kdim // block_k)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, mm, block_k), lambda i, kk: (i, 0, kk)),
            pl.BlockSpec((1, block_k, nn), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((1,), lambda i, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((1, mm, nn), lambda i, kk: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, mm, nn), jnp.int64),
        interpret=True,
    )(x, y, m)
