"""Layer-1 Pallas kernels: the residue-domain hot path of HRFNA.

All kernels are lowered with ``interpret=True`` — real-TPU Pallas emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Correctness is
checked against the pure-jnp oracles in :mod:`compile.kernels.ref`.

Hardware adaptation (paper FPGA -> TPU-style kernels): the k carry-free
residue channels become the leading grid dimension (one program instance
per channel); each channel's MAC chain is tiled into VMEM-sized blocks via
BlockSpec; modular reduction is *deferred* across a block (accumulate in
int64, reduce once per block) — the same exact-arithmetic-between-rare-
reductions principle the paper's RTL applies to normalization.
"""

from .rns_dot import rns_dot
from .rns_matmul import rns_matmul
from .rns_elementwise import rns_modmul, rns_modadd

__all__ = ["rns_dot", "rns_matmul", "rns_modmul", "rns_modadd"]
