"""Pallas kernel: per-channel modular dot product (HRFNA Hybrid Dot Product
inner loop, paper Alg. 1 step 2, residue part).

Given residue-encoded operand matrices ``x, y`` of shape ``(k, n)`` (one row
per residue channel) and the modulus vector ``m`` of shape ``(k,)``, compute

    out[i] = sum_j (x[i, j] * y[i, j])  mod m[i]

Overflow discipline (mirrors the paper's deferred-normalization idea at the
block level): residues are < 2^16, so per-element products are < 2^32. A
block of ``block_n`` products sums to < 2^32 * block_n, which stays inside
int64 for block_n up to 2^31. The running accumulator is reduced mod m once
per block, so the carried value re-enters the next block below 2^16.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Perf (§Perf L1 iteration 1): 512 -> 4096. The deferred-mod overflow
# budget allows blocks up to 2^31 elements; larger blocks shrink the
# sequential grid (interpret-mode while-loop iterations on CPU, HBM->VMEM
# block count on TPU). One 4096-wide int64 block is 32 KiB per operand —
# comfortably VMEM-resident. Measured: 4.6ms -> 1.45ms per 8x4096 dot in
# jitted interpret mode; 2.51ms -> see EXPERIMENTS.md via the PJRT path.
DEFAULT_BLOCK_N = 4096


def _dot_kernel(x_ref, y_ref, m_ref, o_ref):
    """One (channel, block) grid step: block-local MAC + one deferred mod."""
    j = pl.program_id(1)
    m = m_ref[0]

    # Exact block-local multiply-accumulate in int64 (carry-free channel).
    prod = x_ref[0, :] * y_ref[0, :]
    block_sum = jnp.sum(prod) % m

    @pl.when(j == 0)
    def _init():
        o_ref[0] = jnp.zeros((), dtype=o_ref.dtype)

    # One modular reduction per block — the "rare reduction" schedule.
    o_ref[0] = (o_ref[0] + block_sum) % m


@functools.partial(jax.jit, static_argnames=("block_n",))
def rns_dot(x, y, m, *, block_n: int = DEFAULT_BLOCK_N):
    """Residue-domain dot product over k parallel channels.

    Args:
      x, y: int64[k, n] residue matrices, entries in [0, m[i]).
      m:    int64[k] pairwise-coprime moduli (< 2^16 each).
      block_n: tile width along n; n must be a multiple of block_n.

    Returns:
      int64[k]: per-channel dot product residues.
    """
    k, n = x.shape
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    grid = (k, n // block_n)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.int64),
        interpret=True,
    )(x, y, m)
