"""HRFNA compile-time package (build path only; never imported at runtime).

Layer 2 (JAX graphs) and Layer 1 (Pallas kernels) live here. Residue
arithmetic is exact integer math, so the whole package runs under x64.
"""

import jax

# Residue channels use 64-bit integer accumulation (products of 16-bit
# residues summed over blocks); enable x64 before anything traces.
jax.config.update("jax_enable_x64", True)
