"""Layer-2 JAX graphs: the compute graphs the Rust coordinator executes.

Each public function here is AOT-lowered by :mod:`compile.aot` into an HLO
text artifact; the Rust runtime (rust/src/runtime) loads + compiles it with
the PJRT CPU client and drives it from the request path. Hybrid (HRFNA)
graphs call the Layer-1 Pallas kernels; FP32 baseline graphs let Rust push
both formats through one identical execution path for fair comparison.

Shapes are fixed at lowering time (AOT); the Rust batcher buckets requests
into these shapes (see rust/src/coordinator/batcher.rs).
"""

import jax
import jax.numpy as jnp

from .kernels import rns_dot, rns_matmul, rns_modmul, rns_modadd

# Canonical AOT shapes (keep in sync with rust/src/runtime/artifacts.rs).
K_CHANNELS = 8
DOT_N = 4096
MM_DIM = 64
RK4_BATCH = 256


# ---------------------------------------------------------------------------
# HRFNA residue-domain graphs (call Layer-1 Pallas kernels)
# ---------------------------------------------------------------------------

def hybrid_dot(xr, yr, m):
    """Residue part of the Hybrid Dot Product (Alg. 1): int64[k,n] -> int64[k].

    Exponent bookkeeping (f_Z = f_X + f_Y, synchronization) is scalar work
    and stays on the Rust side; this graph is the carry-free hot loop.
    """
    return (rns_dot(xr, yr, m),)


def hybrid_matmul(xr, yr, m):
    """Per-channel modular matmul: int64[k,M,K] x int64[k,K,N] -> int64[k,M,N]."""
    return (rns_matmul(xr, yr, m),)


def hybrid_modmul(xr, yr, m):
    """Elementwise hybrid multiply over a batch of values (Definition 2)."""
    return (rns_modmul(xr, yr, m),)


def hybrid_modadd(xr, yr, m):
    """Residue add for exponent-synchronized operands (§IV-B)."""
    return (rns_modadd(xr, yr, m),)


# ---------------------------------------------------------------------------
# FP32 baseline graphs (vendor-FP32-IP stand-ins, same PJRT path)
# ---------------------------------------------------------------------------

def fp32_dot(x, y):
    """Plain FP32 dot product baseline: f32[n] x f32[n] -> f32[]."""
    return (jnp.dot(x, y),)


def fp32_matmul(a, b):
    """Plain FP32 dense matmul baseline: f32[M,K] x f32[K,N] -> f32[M,N]."""
    return (jnp.matmul(a, b),)


# ---------------------------------------------------------------------------
# RK4 baseline step (Van der Pol oscillator, §VII-D workload)
# ---------------------------------------------------------------------------

def _vdp(state, mu):
    """Van der Pol vector field: x' = v, v' = mu (1 - x^2) v - x."""
    x = state[..., 0]
    v = state[..., 1]
    return jnp.stack([v, mu * (1.0 - x * x) * v - x], axis=-1)


def rk4_vdp_step(state, dt, mu):
    """One classical RK4 step for a batch of Van der Pol states: f32[B,2]."""
    k1 = _vdp(state, mu)
    k2 = _vdp(state + 0.5 * dt * k1, mu)
    k3 = _vdp(state + 0.5 * dt * k2, mu)
    k4 = _vdp(state + dt * k3, mu)
    return (state + (dt / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4),)


# ---------------------------------------------------------------------------
# AOT manifest: name -> (fn, example args)
# ---------------------------------------------------------------------------

def _i64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int64)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


GRAPHS = {
    "hybrid_dot": (hybrid_dot, (_i64(K_CHANNELS, DOT_N), _i64(K_CHANNELS, DOT_N), _i64(K_CHANNELS))),
    "hybrid_matmul": (hybrid_matmul, (_i64(K_CHANNELS, MM_DIM, MM_DIM), _i64(K_CHANNELS, MM_DIM, MM_DIM), _i64(K_CHANNELS))),
    "hybrid_modmul": (hybrid_modmul, (_i64(K_CHANNELS, DOT_N), _i64(K_CHANNELS, DOT_N), _i64(K_CHANNELS))),
    "hybrid_modadd": (hybrid_modadd, (_i64(K_CHANNELS, DOT_N), _i64(K_CHANNELS, DOT_N), _i64(K_CHANNELS))),
    "fp32_dot": (fp32_dot, (_f32(DOT_N), _f32(DOT_N))),
    "fp32_matmul": (fp32_matmul, (_f32(MM_DIM, MM_DIM), _f32(MM_DIM, MM_DIM))),
    "rk4_vdp_step": (rk4_vdp_step, (_f32(RK4_BATCH, 2), _f32(), _f32())),
}
