"""Shared fixtures for the HRFNA python test suite."""

import numpy as np
import pytest

# Default modulus set — keep in sync with rust/src/config/presets
# (k=8 sixteen-bit primes, M ~ 2^127.9).
MODULI = np.array(
    [65521, 65519, 65497, 65479, 65449, 65447, 65437, 65423], dtype=np.int64
)


@pytest.fixture
def moduli():
    return MODULI


def random_residues(rng, m, *shape_tail):
    """Residue tensor with row i uniform in [0, m[i])."""
    k = len(m)
    out = np.empty((k, *shape_tail), dtype=np.int64)
    for i in range(k):
        out[i] = rng.integers(0, m[i], size=shape_tail, dtype=np.int64)
    return out
