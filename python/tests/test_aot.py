"""AOT artifact tests: every graph lowers to parseable HLO text with the
shapes the Rust manifest loader expects."""

import os
import re

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.lower_all(out)
    return out


def test_manifest_lists_every_graph(artifact_dir):
    with open(os.path.join(artifact_dir, "manifest.txt")) as f:
        lines = [l for l in f.read().splitlines() if l.strip()]
    names = {l.split()[0] for l in lines}
    assert names == set(model.GRAPHS.keys())


def test_artifacts_are_hlo_text(artifact_dir):
    for name in model.GRAPHS:
        path = os.path.join(artifact_dir, f"{name}.hlo.txt")
        assert os.path.exists(path), path
        text = open(path).read()
        # HLO text module header + an ENTRY computation.
        assert re.search(r"^HloModule ", text, re.M), name
        assert "ENTRY" in text, name


def test_hybrid_dot_artifact_shapes(artifact_dir):
    text = open(os.path.join(artifact_dir, "hybrid_dot.hlo.txt")).read()
    k, n = model.K_CHANNELS, model.DOT_N
    assert f"s64[{k},{n}]" in text
    assert f"s64[{k}]" in text


def test_fp32_artifacts_have_f32_entry(artifact_dir):
    text = open(os.path.join(artifact_dir, "fp32_dot.hlo.txt")).read()
    assert f"f32[{model.DOT_N}]" in text


def test_manifest_arg_descriptors_parse(artifact_dir):
    """Arg descriptors follow dtype[shape] — the Rust side parses these."""
    pat = re.compile(r"^(int64|float32)\[[\d, ]*\]$")
    with open(os.path.join(artifact_dir, "manifest.txt")) as f:
        for line in f:
            if not line.strip():
                continue
            _, _, args_desc = line.split(" ", 2)
            for a in args_desc.strip().split(";"):
                assert pat.match(a), a
