"""Layer-2 graph tests: model fns produce correct numerics + expected shapes."""

import numpy as np
from compile import model
from compile.kernels.ref import ref_dot, ref_matmul
from .conftest import MODULI, random_residues


def test_hybrid_dot_graph():
    rng = np.random.default_rng(0)
    x = random_residues(rng, MODULI, model.DOT_N)
    y = random_residues(rng, MODULI, model.DOT_N)
    (out,) = model.hybrid_dot(x, y, MODULI)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_dot(x, y, MODULI)))


def test_hybrid_matmul_graph():
    rng = np.random.default_rng(1)
    x = random_residues(rng, MODULI, model.MM_DIM, model.MM_DIM)
    y = random_residues(rng, MODULI, model.MM_DIM, model.MM_DIM)
    (out,) = model.hybrid_matmul(x, y, MODULI)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref_matmul(x, y, MODULI))
    )


def test_fp32_dot_graph():
    rng = np.random.default_rng(2)
    x = rng.standard_normal(model.DOT_N).astype(np.float32)
    y = rng.standard_normal(model.DOT_N).astype(np.float32)
    (out,) = model.fp32_dot(x, y)
    np.testing.assert_allclose(float(out), float(np.dot(x, y)), rtol=1e-5)


def test_rk4_step_against_numpy():
    """One RK4 step on the Van der Pol field vs a numpy re-implementation."""
    rng = np.random.default_rng(3)
    state = rng.standard_normal((model.RK4_BATCH, 2)).astype(np.float32)
    dt, mu = np.float32(0.01), np.float32(1.5)

    def vdp(s):
        x, v = s[..., 0], s[..., 1]
        return np.stack([v, mu * (1.0 - x * x) * v - x], axis=-1)

    k1 = vdp(state)
    k2 = vdp(state + 0.5 * dt * k1)
    k3 = vdp(state + 0.5 * dt * k2)
    k4 = vdp(state + dt * k3)
    want = state + (dt / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4)

    (got,) = model.rk4_vdp_step(state, dt, mu)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_rk4_energy_decay_harmonic_limit():
    """mu=0 reduces Van der Pol to the harmonic oscillator: RK4 should
    conserve x^2+v^2 to O(dt^4) per step."""
    state = np.array([[1.0, 0.0]] * model.RK4_BATCH, dtype=np.float32)
    dt, mu = np.float32(0.001), np.float32(0.0)
    s = state
    for _ in range(100):
        (s,) = model.rk4_vdp_step(s, dt, mu)
    s = np.asarray(s)
    energy = s[:, 0] ** 2 + s[:, 1] ** 2
    np.testing.assert_allclose(energy, 1.0, atol=1e-5)


def test_graph_manifest_entries_lower():
    """Every GRAPHS entry must lower to StableHLO without error."""
    import jax

    for name, (fn, args) in model.GRAPHS.items():
        lowered = jax.jit(fn).lower(*args)
        assert lowered is not None, name
