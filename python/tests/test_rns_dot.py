"""Layer-1 rns_dot Pallas kernel vs pure-jnp and exact-int oracles."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings, strategies as st

from compile.kernels import rns_dot
from compile.kernels.ref import ref_dot, exact_dot
from .conftest import MODULI, random_residues


def test_dot_matches_ref_default_shape():
    rng = np.random.default_rng(0)
    x = random_residues(rng, MODULI, 4096)
    y = random_residues(rng, MODULI, 4096)
    got = np.asarray(rns_dot(x, y, MODULI))
    want = np.asarray(ref_dot(x, y, MODULI))
    np.testing.assert_array_equal(got, want)


def test_dot_matches_exact_small():
    rng = np.random.default_rng(1)
    x = random_residues(rng, MODULI, 512)
    y = random_residues(rng, MODULI, 512)
    got = np.asarray(rns_dot(x, y, MODULI, block_n=128))
    want = exact_dot(x, y, MODULI)
    np.testing.assert_array_equal(got, want)


def test_dot_zero_operand():
    rng = np.random.default_rng(2)
    x = random_residues(rng, MODULI, 512)
    z = np.zeros_like(x)
    got = np.asarray(rns_dot(x, z, MODULI, block_n=256))
    np.testing.assert_array_equal(got, np.zeros(len(MODULI), dtype=np.int64))


def test_dot_ones_counts_length():
    n = 1024
    ones = np.ones((len(MODULI), n), dtype=np.int64)
    got = np.asarray(rns_dot(ones, ones, MODULI, block_n=256))
    want = np.array([n % m for m in MODULI], dtype=np.int64)
    np.testing.assert_array_equal(got, want)


def test_dot_max_residues_no_overflow():
    """All residues at m-1: the worst-case block sum must stay exact."""
    k = len(MODULI)
    n = 2048
    x = np.tile((MODULI - 1)[:, None], (1, n))
    got = np.asarray(rns_dot(x, x, MODULI, block_n=512))
    want = exact_dot(x, x, MODULI)
    np.testing.assert_array_equal(got, want)


def test_dot_rejects_non_multiple_block():
    x = np.ones((len(MODULI), 100), dtype=np.int64)
    with pytest.raises(ValueError):
        rns_dot(x, x, MODULI, block_n=64)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    log_n=st.integers(1, 5),
    block_pow=st.integers(0, 3),
    k=st.integers(1, 8),
)
def test_dot_hypothesis_shapes(seed, log_n, block_pow, k):
    """Sweep (k, n, block_n) against the exact python-int oracle."""
    rng = np.random.default_rng(seed)
    m = MODULI[:k]
    block_n = 2 ** (4 + block_pow)          # 16..128
    n = block_n * (2 ** log_n)              # up to 4096
    x = random_residues(rng, m, n)
    y = random_residues(rng, m, n)
    got = np.asarray(rns_dot(x, y, m, block_n=block_n))
    want = np.asarray(ref_dot(x, y, m))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_ref_dot_matches_exact(seed):
    """The jnp oracle itself is validated against arbitrary-precision ints."""
    rng = np.random.default_rng(seed)
    x = random_residues(rng, MODULI, 256)
    y = random_residues(rng, MODULI, 256)
    np.testing.assert_array_equal(
        np.asarray(ref_dot(x, y, MODULI)), exact_dot(x, y, MODULI)
    )
