"""Layer-1 rns_matmul Pallas kernel vs oracles."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings, strategies as st

from compile.kernels import rns_matmul
from compile.kernels.ref import ref_matmul, exact_matmul
from .conftest import MODULI, random_residues


def test_matmul_matches_ref_64():
    rng = np.random.default_rng(0)
    x = random_residues(rng, MODULI, 64, 64)
    y = random_residues(rng, MODULI, 64, 64)
    got = np.asarray(rns_matmul(x, y, MODULI, block_k=32))
    want = np.asarray(ref_matmul(x, y, MODULI))
    np.testing.assert_array_equal(got, want)


def test_matmul_matches_exact_small():
    rng = np.random.default_rng(1)
    x = random_residues(rng, MODULI, 8, 16)
    y = random_residues(rng, MODULI, 16, 8)
    got = np.asarray(rns_matmul(x, y, MODULI, block_k=16))
    want = exact_matmul(x, y, MODULI)
    np.testing.assert_array_equal(got, want)


def test_matmul_identity():
    k = len(MODULI)
    n = 32
    rng = np.random.default_rng(2)
    x = random_residues(rng, MODULI, n, n)
    eye = np.tile(np.eye(n, dtype=np.int64)[None], (k, 1, 1))
    got = np.asarray(rns_matmul(x, eye, MODULI, block_k=32))
    np.testing.assert_array_equal(got, x)


def test_matmul_rectangular():
    rng = np.random.default_rng(3)
    x = random_residues(rng, MODULI, 16, 64)
    y = random_residues(rng, MODULI, 64, 48)
    got = np.asarray(rns_matmul(x, y, MODULI, block_k=16))
    want = np.asarray(ref_matmul(x, y, MODULI))
    np.testing.assert_array_equal(got, want)


def test_matmul_rejects_bad_block():
    x = np.ones((len(MODULI), 8, 30), dtype=np.int64)
    y = np.ones((len(MODULI), 30, 8), dtype=np.int64)
    with pytest.raises(ValueError):
        rns_matmul(x, y, MODULI, block_k=16)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    mm=st.integers(1, 24),
    nn=st.integers(1, 24),
    kblocks=st.integers(1, 4),
    k=st.integers(1, 8),
)
def test_matmul_hypothesis(seed, mm, nn, kblocks, k):
    rng = np.random.default_rng(seed)
    m = MODULI[:k]
    block_k = 16
    kdim = block_k * kblocks
    x = random_residues(rng, m, mm, kdim)
    y = random_residues(rng, m, kdim, nn)
    got = np.asarray(rns_matmul(x, y, m, block_k=block_k))
    want = exact_matmul(x, y, m)
    np.testing.assert_array_equal(got, want)
