"""Test package marker: makes `from .conftest import ...` resolve when
pytest imports these modules with `python/` on sys.path."""
