"""Layer-1 elementwise modmul/modadd kernels vs oracles."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip cleanly when absent
from hypothesis import given, settings, strategies as st

from compile.kernels import rns_modmul, rns_modadd
from compile.kernels.ref import ref_modmul, ref_modadd
from .conftest import MODULI, random_residues


def test_modmul_matches_ref():
    rng = np.random.default_rng(0)
    x = random_residues(rng, MODULI, 4096)
    y = random_residues(rng, MODULI, 4096)
    np.testing.assert_array_equal(
        np.asarray(rns_modmul(x, y, MODULI)),
        np.asarray(ref_modmul(x, y, MODULI)),
    )


def test_modadd_matches_ref():
    rng = np.random.default_rng(1)
    x = random_residues(rng, MODULI, 4096)
    y = random_residues(rng, MODULI, 4096)
    np.testing.assert_array_equal(
        np.asarray(rns_modadd(x, y, MODULI)),
        np.asarray(ref_modadd(x, y, MODULI)),
    )


def test_modmul_by_one_is_identity():
    rng = np.random.default_rng(2)
    x = random_residues(rng, MODULI, 1024)
    ones = np.ones_like(x)
    np.testing.assert_array_equal(np.asarray(rns_modmul(x, ones, MODULI)), x)


def test_modadd_inverse_pairs_cancel():
    """x + (m - x) ≡ 0 (mod m), elementwise in every channel."""
    rng = np.random.default_rng(3)
    x = random_residues(rng, MODULI, 1024)
    neg = (MODULI[:, None] - x) % MODULI[:, None]
    got = np.asarray(rns_modadd(x, neg, MODULI))
    np.testing.assert_array_equal(got, np.zeros_like(x))


def test_modmul_max_residues():
    """(m-1)^2 mod m == 1 — worst-case magnitudes stay exact."""
    k = len(MODULI)
    n = 1024
    x = np.tile((MODULI - 1)[:, None], (1, n))
    got = np.asarray(rns_modmul(x, x, MODULI))
    np.testing.assert_array_equal(got, np.ones((k, n), dtype=np.int64))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    blocks=st.integers(1, 8),
    k=st.integers(1, 8),
    op=st.sampled_from(["mul", "add"]),
)
def test_elementwise_hypothesis(seed, blocks, k, op):
    rng = np.random.default_rng(seed)
    m = MODULI[:k]
    n = 128 * blocks
    x = random_residues(rng, m, n)
    y = random_residues(rng, m, n)
    if op == "mul":
        got = np.asarray(rns_modmul(x, y, m, block_n=128))
        want = np.asarray(ref_modmul(x, y, m))
    else:
        got = np.asarray(rns_modadd(x, y, m, block_n=128))
        want = np.asarray(ref_modadd(x, y, m))
    np.testing.assert_array_equal(got, want)
